"""``tdp.autotune`` — the Program-level tuner over ``Target.tuning``.

Deterministic throughout: measurement runs under an *injected fake
timer* (scripted per-candidate costs — the pluggable-timer contract), so
these tests assert selection logic, pruning, caching and correctness
decoupling without ever depending on wall-clock noise:

* **best-candidate selection** — argmin of the scripted medians, with
  the base target always measured as candidate 0 (tuned median ≤
  default median by construction);
* **space construction** — executor axis capability-checked, the
  ``plane_block`` divisor sweep, VMEM-infeasibility pruning;
* **cache** — miss measures + writes ``<cache_dir>/<key>.json``, hit
  replays the stored choice without calling the timer at all;
* **correctness decoupling** — 5-step LB trajectories are bit-identical
  under *every* candidate in a small space (xla vs tuned
  pallas_interpret / pallas_windowed_interpret), and
  ``check_identical=True`` prunes an executor that lies.
"""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro import tdp
from repro.core import Lattice, STENCIL_GRAD_6PT
from repro.core.autotune import cache_key
from repro.lb import programs as lbp
from repro.lb.params import LBParams

GRID = (8, 8, 8)
PARAMS = LBParams(A=0.125, B=0.125, kappa=0.02)
WT = tdp.Target("pallas_windowed", interpret=True)


def fused_prog(mode="two_launch"):
    return lbp.fused_program(
        mode, lbp.collision_consts(**PARAMS.as_kwargs()))


def lb_state(grid=GRID, seed=0):
    rng = np.random.default_rng(seed)
    f = jnp.asarray(0.05 * rng.normal(size=(19,) + grid) + 1 / 19.,
                    jnp.float32)
    g = jnp.asarray(0.05 * rng.normal(size=(19,) + grid), jnp.float32)
    return {"f": f, "g": g}


class ScriptedTimer:
    """Fake timer: cost per candidate label, call log kept."""

    def __init__(self, costs, default=1.0):
        self.costs = dict(costs)
        self.default = default
        self.calls = []

    def __call__(self, target, run):
        label = tdp.Candidate.of(target).label
        self.calls.append(label)
        for key, cost in self.costs.items():
            if key in label:
                return cost
        return self.default


@tdp.kernel(fields=[tdp.field(2)], out=2)
def double2(x):
    return 2.0 * x


@tdp.kernel(fields=[tdp.field(1, stencil=STENCIL_GRAD_6PT)], out=1)
def star_sum(p):
    acc = p[0, 0]
    for i in range(1, 7):
        acc = acc + p[i, 0]
    return acc[None]


# ---------------------------------------------------------------------------
# space construction
# ---------------------------------------------------------------------------

class TestSpace:
    def test_program_space_has_base_xla_and_divisor_sweep(self):
        cands, pruned = tdp.default_space(fused_prog(), WT, grid_shape=GRID)
        labels = [c.label for c in cands]
        assert labels[0] == "pallas_windowed_interpret"      # the base
        assert "xla" in labels
        pbs = [dict(c.tuning)["plane_block"] for c in cands
               if "plane_block" in dict(c.tuning)]
        assert pbs == [1, 2, 4, 8]                           # divisors of 8
        assert all(GRID[0] % p == 0 for p in pbs)
        assert pruned == []

    def test_vmem_limit_prunes_large_plane_blocks(self):
        cands, pruned = tdp.default_space(fused_prog(), WT, grid_shape=GRID,
                                          vmem_limit=1)
        assert all("plane_block" not in dict(c.tuning) for c in cands)
        assert pruned and all("vmem estimate" in why for _, why in pruned)

    def test_pointwise_spec_excludes_halo_extended_executors(self):
        x = jnp.ones((2, 32), jnp.float32)
        cands, pruned = tdp.default_space(
            double2, tdp.Target("xla"),
            executors=("xla", "pallas_windowed"))
        labels = [c.label for c in cands]
        assert "pallas_windowed" not in labels
        assert any("halo_extended" in why for _, why in pruned)
        del x

    def test_pointwise_pallas_axis_sweeps_declared_block_knobs(self):
        cands, _ = tdp.default_space(
            double2, tdp.Target("xla"),
            executors=("xla", "pallas_interpret"))
        knobs = {k for c in cands for k, _ in c.tuning}
        assert "block_f" in knobs                  # declared tunable
        assert "plane_block" not in knobs          # not on this executor

    def test_stencil_spec_plane_block_candidates(self):
        lat = Lattice((12, 4, 4))
        feasible, pruned = tdp.plane_block_candidates(star_sum, WT, lat)
        assert feasible == [1, 2, 3, 4, 6, 12]
        assert pruned == []
        feasible, pruned = tdp.plane_block_candidates(
            star_sum, WT, lat, vmem_limit=0)
        assert feasible == [] and len(pruned) == 6


# ---------------------------------------------------------------------------
# selection with a fake timer
# ---------------------------------------------------------------------------

class TestSelection:
    def test_best_candidate_wins(self, tmp_path):
        timer = ScriptedTimer({"plane_block=4": 0.01, "xla": 0.5},
                              default=1.0)
        tuned, report = tdp.autotune(
            fused_prog(), WT, lb_state(), timer=timer,
            cache_dir=str(tmp_path), reps=3, warmup=0, measure_steps=1)
        assert report.best.label == "pallas_windowed_interpret[plane_block=4]"
        assert tuned.backend == "pallas_windowed" and tuned.interpret
        assert tuned.tune("plane_block") == 4
        assert report.best_median_s == pytest.approx(0.01)
        assert report.default_median_s == pytest.approx(1.0)
        assert report.best_median_s <= report.default_median_s

    def test_base_target_always_candidate_zero(self, tmp_path):
        timer = ScriptedTimer({}, default=1.0)   # flat costs: base wins ties
        tuned, report = tdp.autotune(
            fused_prog(), WT, lb_state(), timer=timer,
            cache_dir=str(tmp_path), reps=1, warmup=0)
        assert report.results[0].candidate.label == \
            "pallas_windowed_interpret"
        assert tuned.executor == WT.executor

    def test_budget_keeps_base_and_prunes_tail(self, tmp_path):
        timer = ScriptedTimer({}, default=1.0)
        _, report = tdp.autotune(
            fused_prog(), WT, lb_state(), timer=timer,
            cache_dir=str(tmp_path), budget=2, reps=1, warmup=0)
        assert len(report.results) == 2
        assert report.results[0].candidate.label == \
            "pallas_windowed_interpret"
        assert any("over budget" in why for _, why in report.pruned)

    def test_explicit_space_of_targets(self, tmp_path):
        timer = ScriptedTimer({"xla": 0.1}, default=1.0)
        tuned, report = tdp.autotune(
            fused_prog(), WT, lb_state(), timer=timer,
            space=["xla", WT.with_tuning(plane_block=2)],
            cache_dir=str(tmp_path), reps=1, warmup=0)
        assert tuned.executor == "xla"
        # the base was prepended even though the space didn't name it
        assert report.results[0].candidate.label == \
            "pallas_windowed_interpret"

    def test_explicit_space_listing_base_elsewhere_keeps_it_first(
            self, tmp_path):
        """Candidate 0 is the base target even when the space lists it at
        a later index — the default-median baseline must be the base."""
        timer = ScriptedTimer({"xla": 0.1}, default=1.0)
        _, report = tdp.autotune(
            fused_prog(), WT, lb_state(), timer=timer,
            space=["xla", WT], cache_dir=str(tmp_path), reps=1, warmup=0)
        labels = [r.candidate.label for r in report.results]
        assert labels[0] == "pallas_windowed_interpret"
        assert labels.count("pallas_windowed_interpret") == 1
        assert report.default_median_s == pytest.approx(1.0)   # not xla's

    def test_program_autotune_convenience(self, tmp_path):
        timer = ScriptedTimer({}, default=1.0)
        tuned, report = fused_prog().autotune(
            WT, lb_state(), timer=timer, cache_dir=str(tmp_path),
            reps=1, warmup=0)
        assert isinstance(report, tdp.TuneReport)
        assert tuned.executor == WT.executor

    def test_unrunnable_candidate_is_pruned_not_fatal(self, tmp_path):
        calls = {"n": 0}

        def exploding(target, run):
            calls["n"] += 1
            if target.executor == "xla":
                raise RuntimeError("boom")
            return 1.0

        _, report = tdp.autotune(
            fused_prog(), WT, lb_state(), timer=exploding,
            cache_dir=str(tmp_path), reps=1, warmup=0)
        assert any("boom" in why for label, why in report.pruned
                   if label == "xla")
        assert all(r.candidate.label != "xla" for r in report.results)


# ---------------------------------------------------------------------------
# the on-disk cache
# ---------------------------------------------------------------------------

class TestCache:
    def test_miss_writes_then_hit_skips_measurement(self, tmp_path):
        timer = ScriptedTimer({"plane_block=2": 0.01}, default=1.0)
        prog = fused_prog()
        tuned1, rep1 = tdp.autotune(prog, WT, lb_state(), timer=timer,
                                    cache_dir=str(tmp_path), reps=1,
                                    warmup=0)
        assert not rep1.cache_hit
        path = os.path.join(str(tmp_path), f"{rep1.cache_key}.json")
        assert os.path.exists(path)
        n_calls = len(timer.calls)
        assert n_calls > 0

        tuned2, rep2 = tdp.autotune(prog, WT, lb_state(), timer=timer,
                                    cache_dir=str(tmp_path), reps=1,
                                    warmup=0)
        assert rep2.cache_hit
        assert len(timer.calls) == n_calls          # no re-measurement
        assert tuned2 == tuned1
        assert rep2.best == rep1.best

    def test_cache_key_discriminates_grid_backend_and_graph(self):
        prog = fused_prog()
        k = cache_key(prog, WT, (8, 8, 8))
        assert k != cache_key(prog, WT, (16, 8, 8))
        assert k != cache_key(prog, tdp.Target("xla"), (8, 8, 8))
        assert k != cache_key(fused_prog("one_launch"), WT, (8, 8, 8))
        # interpreter-measured tuning must never answer for compiled runs
        assert k != cache_key(prog, tdp.Target("pallas_windowed"),
                              (8, 8, 8))
        # stable across calls (no PYTHONHASHSEED dependence)
        assert k == cache_key(fused_prog(), WT, (8, 8, 8))

    def test_corrupt_cache_entry_is_a_miss(self, tmp_path):
        timer = ScriptedTimer({}, default=1.0)
        prog = fused_prog()
        _, rep = tdp.autotune(prog, WT, lb_state(), timer=timer,
                              cache_dir=str(tmp_path), reps=1, warmup=0)
        path = os.path.join(str(tmp_path), f"{rep.cache_key}.json")
        with open(path, "w") as fh:
            fh.write("{not json")
        _, rep2 = tdp.autotune(prog, WT, lb_state(), timer=timer,
                               cache_dir=str(tmp_path), reps=1, warmup=0)
        assert not rep2.cache_hit                   # re-measured
        with open(path) as fh:
            assert json.load(fh)["cache_key"] == rep.cache_key

    def test_interrupted_write_preserves_previous_entry(self, tmp_path,
                                                        monkeypatch):
        """A writer killed mid-``json.dump`` must not clobber the existing
        cache entry: the dump goes to a tempfile and only a completed one
        is ``os.replace``d over the real path."""
        import importlib
        at = importlib.import_module("repro.core.autotune")

        timer = ScriptedTimer({"plane_block=2": 0.01}, default=1.0)
        prog = fused_prog()
        _, rep1 = tdp.autotune(prog, WT, lb_state(), timer=timer,
                               cache_dir=str(tmp_path), reps=1, warmup=0)
        path = os.path.join(str(tmp_path), f"{rep1.cache_key}.json")
        with open(path) as fh:
            before = fh.read()

        real_dump = json.dump

        def dying_dump(obj, fh, **kw):
            fh.write('{"cache_key": "half-writ')    # partial bytes...
            fh.flush()
            raise KeyboardInterrupt("killed mid-write")   # ...then death

        monkeypatch.setattr(at.json, "dump", dying_dump)
        rep_fake = at.TuneReport.from_dict(rep1.as_dict(), cache_hit=False)
        with pytest.raises(KeyboardInterrupt):
            at.store_cached(str(tmp_path), rep_fake)
        monkeypatch.setattr(at.json, "dump", real_dump)

        with open(path) as fh:
            assert fh.read() == before          # old entry intact
        assert json.loads(before)["cache_key"] == rep1.cache_key
        # no orphaned tempfiles left behind
        leftovers = [n for n in os.listdir(str(tmp_path))
                     if n.endswith(".tmp")]
        assert leftovers == []
        # and the entry still replays as a hit
        _, rep2 = tdp.autotune(prog, WT, lb_state(), timer=timer,
                               cache_dir=str(tmp_path), reps=1, warmup=0)
        assert rep2.cache_hit

    def test_concurrent_writers_leave_valid_entry(self, tmp_path):
        """N threads racing ``store_cached`` on the same key: the final
        file is one complete JSON document (some writer's replace wins
        whole — never an interleaving)."""
        import importlib
        import threading

        at = importlib.import_module("repro.core.autotune")

        timer = ScriptedTimer({}, default=1.0)
        _, rep = tdp.autotune(fused_prog(), WT, lb_state(), timer=timer,
                              cache_dir=str(tmp_path), reps=1, warmup=0)
        path = os.path.join(str(tmp_path), f"{rep.cache_key}.json")
        errs = []

        def write(i):
            try:
                r = at.TuneReport.from_dict(rep.as_dict(), cache_hit=False)
                for _ in range(20):
                    at.store_cached(str(tmp_path), r)
            except Exception as e:       # pragma: no cover - failure path
                errs.append(e)

        threads = [threading.Thread(target=write, args=(i,))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert errs == []
        with open(path) as fh:
            assert json.load(fh)["cache_key"] == rep.cache_key
        assert not [n for n in os.listdir(str(tmp_path))
                    if n.endswith(".tmp")]

    def test_cache_dir_none_disables(self, tmp_path):
        timer = ScriptedTimer({}, default=1.0)
        _, rep = tdp.autotune(fused_prog(), WT, lb_state(), timer=timer,
                              cache_dir=None, reps=1, warmup=0)
        assert not rep.cache_hit
        assert os.listdir(str(tmp_path)) == []

    def test_report_round_trips_through_json(self, tmp_path):
        timer = ScriptedTimer({"xla": 0.25}, default=1.0)
        _, rep = tdp.autotune(fused_prog(), WT, lb_state(), timer=timer,
                              cache_dir=str(tmp_path), reps=2, warmup=0)
        rebuilt = tdp.TuneReport.from_dict(rep.as_dict(), cache_hit=True)
        assert rebuilt.best == rep.best
        assert rebuilt.results == rep.results
        assert rebuilt.cache_key == rep.cache_key
        assert rebuilt.cache_hit


# ---------------------------------------------------------------------------
# correctness is decoupled from tuning
# ---------------------------------------------------------------------------

class TestCorrectnessDecoupling:
    @pytest.mark.parametrize("mode", ["one_launch", "two_launch"])
    def test_five_step_trajectories_bit_identical_under_all_candidates(
            self, mode):
        """Every candidate in the small space — xla and the tuned
        pallas_interpret / pallas_windowed_interpret variants — steps the
        LB program to bit-identical 5-step trajectories."""
        prog = fused_prog(mode)
        state = lb_state()
        space = [
            tdp.Target("xla"),
            tdp.Target("pallas_interpret"),
            WT,                                       # plane_block default
            WT.with_tuning(plane_block=2),
            WT.with_tuning(plane_block=4),
        ]
        ref = None
        for tgt in space:
            exe = prog.compile(tgt, grid_shape=GRID)
            out = exe.run(dict(state), 5)
            got = {k: np.asarray(v) for k, v in out.items()}
            if ref is None:
                ref = got
                continue
            for k in ref:
                np.testing.assert_array_equal(
                    ref[k], got[k],
                    err_msg=f"{tgt} diverges from xla on field {k!r}")

    def test_check_identical_accepts_honest_candidates(self, tmp_path):
        timer = ScriptedTimer({}, default=1.0)
        _, rep = tdp.autotune(
            fused_prog(), WT, lb_state(), timer=timer,
            cache_dir=str(tmp_path), reps=1, warmup=0, measure_steps=2,
            check_identical=True)
        # xla and every feasible plane_block variant all survive
        assert {r.candidate.label for r in rep.results} >= {
            "pallas_windowed_interpret", "xla"}
        assert not any("bit-identical" in why for _, why in rep.pruned)

    def test_check_identical_prunes_a_lying_executor(self, tmp_path):
        def lying(plan, prepared):
            outs = tdp.xla_executor(plan, prepared)
            return tuple(o + 1e-3 for o in outs)

        tdp.register_executor("lying_xla", lying)
        try:
            timer = ScriptedTimer({"lying_xla": 0.001}, default=1.0)
            tuned, rep = tdp.autotune(
                fused_prog(), tdp.Target("xla"), lb_state(), timer=timer,
                space=[tdp.Target("lying_xla")], cache_dir=str(tmp_path),
                reps=1, warmup=0, check_identical=True)
            assert any("bit-identical" in why for label, why in rep.pruned
                       if label == "lying_xla")
            assert tuned.executor == "xla"      # cheapest honest candidate
        finally:
            tdp.unregister_executor("lying_xla")


# ---------------------------------------------------------------------------
# predictor-guided search (the costmodel scorer + top_k)
# ---------------------------------------------------------------------------

def scripted_scorer(costs, default=0.05):
    """Fake scorer keyed by label substring, mirroring ScriptedTimer."""
    def scorer(target):
        label = tdp.Candidate.of(target).label
        for key, cost in costs.items():
            if key in label:
                return cost
        return default
    return scorer


class TestPredictorGuided:
    def test_top_k_measures_at_most_k_plus_one(self, tmp_path):
        timer = ScriptedTimer({}, default=1.0)
        scorer = scripted_scorer({"plane_block=4": 0.001,
                                  "plane_block=2": 0.002})
        _, rep = tdp.autotune(
            fused_prog(), WT, lb_state(), timer=timer, scorer=scorer,
            top_k=2, cache_dir=str(tmp_path), reps=1, warmup=0)
        measured = [r.candidate.label for r in rep.results]
        assert len(measured) <= 3                      # K + the base
        assert "pallas_windowed_interpret[plane_block=4]" in measured
        assert "pallas_windowed_interpret[plane_block=2]" in measured

    def test_candidate_zero_never_model_pruned(self, tmp_path):
        timer = ScriptedTimer({}, default=1.0)
        # the base target scores WORST — it must still be measured
        scorer = scripted_scorer({}, default=0.001)

        def worst_for_base(target):
            label = tdp.Candidate.of(target).label
            return 99.0 if label == "pallas_windowed_interpret" else 0.001

        _, rep = tdp.autotune(
            fused_prog(), WT, lb_state(), timer=timer,
            scorer=worst_for_base, top_k=1, cache_dir=str(tmp_path),
            reps=1, warmup=0)
        assert rep.results[0].candidate.label == "pallas_windowed_interpret"
        assert not any(label == "pallas_windowed_interpret"
                       for label, _ in rep.pruned)

    def test_model_pruned_candidates_recorded_with_reason(self, tmp_path):
        timer = ScriptedTimer({}, default=1.0)
        scorer = scripted_scorer({"plane_block=4": 0.001})
        _, rep = tdp.autotune(
            fused_prog(), WT, lb_state(), timer=timer, scorer=scorer,
            top_k=1, cache_dir=str(tmp_path), reps=1, warmup=0)
        mp = [(label, why) for label, why in rep.pruned
              if why.startswith("model-pruned")]
        assert mp, "pruned-by-the-model candidates must be recorded"
        assert all("predicted rank" in why for _, why in mp)

    def test_unscored_candidates_pruned_not_crashed(self, tmp_path):
        timer = ScriptedTimer({}, default=1.0)

        def flaky(target):
            label = tdp.Candidate.of(target).label
            if "plane_block" in label:
                raise RuntimeError("no estimate for you")
            return 0.01

        _, rep = tdp.autotune(
            fused_prog(), WT, lb_state(), timer=timer, scorer=flaky,
            top_k=2, cache_dir=str(tmp_path), reps=1, warmup=0)
        assert any("no estimate" in why for _, why in rep.pruned)
        assert rep.results     # the runnable scored set still measured

    def test_predictions_annotate_results_and_round_trip(self, tmp_path):
        timer = ScriptedTimer({"plane_block=4": 0.01}, default=0.1)
        scorer = scripted_scorer({"plane_block=4": 0.005}, default=0.2)
        _, rep = tdp.autotune(
            fused_prog(), WT, lb_state(), timer=timer, scorer=scorer,
            cache_dir=str(tmp_path), reps=1, warmup=0)
        for r in rep.results:
            assert r.predicted_s is not None
            assert r.predicted_vs_measured == pytest.approx(
                (r.predicted_s - r.median_s) / r.median_s)
        assert rep.rank_correlation is not None
        rebuilt = tdp.TuneReport.from_dict(rep.as_dict(), cache_hit=True)
        assert rebuilt.results == rep.results
        assert rebuilt.rank_correlation == pytest.approx(
            rep.rank_correlation)

    def test_perfect_scorer_gives_rank_correlation_one(self, tmp_path):
        costs = {"plane_block=4": 0.01, "plane_block=2": 0.02, "xla": 0.5}
        timer = ScriptedTimer(costs, default=1.0)
        scorer = scripted_scorer(
            {k: v / 10 for k, v in costs.items()}, default=0.1)
        _, rep = tdp.autotune(
            fused_prog(), WT, lb_state(), timer=timer, scorer=scorer,
            cache_dir=str(tmp_path), reps=1, warmup=0)
        assert rep.rank_correlation == pytest.approx(1.0)

    def test_default_costmodel_scorer_scores_everything(self, tmp_path):
        timer = ScriptedTimer({}, default=1.0)
        _, rep = tdp.autotune(
            fused_prog(), WT, lb_state(), timer=timer, top_k=2,
            cache_dir=str(tmp_path), reps=1, warmup=0)
        assert len(rep.results) <= 3
        assert all(r.predicted_s is not None and r.predicted_s > 0
                   for r in rep.results)


# ---------------------------------------------------------------------------
# cache schema versioning
# ---------------------------------------------------------------------------

class TestCacheSchema:
    def _one_report(self, tmp_path):
        timer = ScriptedTimer({"xla": 0.25}, default=1.0)
        _, rep = tdp.autotune(fused_prog(), WT, lb_state(), timer=timer,
                              cache_dir=str(tmp_path), reps=1, warmup=0)
        (entry,) = [n for n in os.listdir(str(tmp_path))
                    if n.endswith(".json")]
        return rep, os.path.join(str(tmp_path), entry)

    def test_entries_carry_current_schema(self, tmp_path):
        rep, path = self._one_report(tmp_path)
        from repro.core.autotune import SCHEMA_VERSION
        assert rep.schema == SCHEMA_VERSION == 3
        with open(path) as fh:
            assert json.load(fh)["schema"] == SCHEMA_VERSION

    def test_v1_entry_still_replays(self, tmp_path):
        rep, path = self._one_report(tmp_path)
        d = json.load(open(path))
        del d["schema"]                        # v1 entries had no field
        del d["rank_correlation"]
        for r in d["candidates"]:
            r.pop("predicted_s", None)
            r.pop("predicted_vs_measured", None)
        json.dump(d, open(path, "w"))
        timer = ScriptedTimer({}, default=1.0)
        _, rep2 = tdp.autotune(fused_prog(), WT, lb_state(), timer=timer,
                               cache_dir=str(tmp_path), reps=1, warmup=0)
        assert rep2.cache_hit
        assert timer.calls == []
        assert rep2.best == rep.best
        assert all(r.predicted_s is None for r in rep2.results)

    def test_future_schema_is_a_miss(self, tmp_path):
        rep, path = self._one_report(tmp_path)
        d = json.load(open(path))
        d["schema"] = 99
        json.dump(d, open(path, "w"))
        timer = ScriptedTimer({}, default=1.0)
        _, rep2 = tdp.autotune(fused_prog(), WT, lb_state(), timer=timer,
                               cache_dir=str(tmp_path), reps=1, warmup=0)
        assert not rep2.cache_hit
        assert timer.calls != []               # re-measured from scratch


# ---------------------------------------------------------------------------
# per-stage tuning assignments
# ---------------------------------------------------------------------------

class TestPerStage:
    def test_space_gains_stage_candidates(self):
        cands, _ = tdp.default_space(
            fused_prog("two_launch"), WT, grid_shape=GRID,
            executors=["pallas_windowed"], per_stage=True)
        stage_keys = {k for c in cands for k, _ in c.tuning
                      if k.startswith("stage:")}
        assert stage_keys == {"stage:phi_stream", "stage:fused_two"}

    def test_single_windowed_stage_skips_the_axis(self):
        # one windowed stage makes per-stage ≡ the global sweep
        cands, _ = tdp.default_space(
            fused_prog("one_launch"), WT, grid_shape=GRID,
            executors=["pallas_windowed"], per_stage=True)
        assert not any(k.startswith("stage:")
                       for c in cands for k, _ in c.tuning)

    def test_resolve_stage_target_merges_only_its_stage(self):
        from repro.core.program import resolve_stage_target
        prog = fused_prog("two_launch")
        tgt = WT.with_tuning({"stage:fused_two": (("plane_block", 4),)})
        pplan = prog.plan(tgt, grid_shape=GRID)
        by_stage = {n: p.target.tuning for n, p in pplan.stages}
        assert by_stage["fused_two"] == (("plane_block", 4),)
        assert by_stage["phi_stream"] == ()
        del resolve_stage_target

    def test_per_stage_candidates_run_bit_identical(self):
        prog = fused_prog("two_launch")
        state = lb_state()
        base = prog.compile(WT, grid_shape=GRID)
        ref = {k: np.asarray(v)
               for k, v in base.run(dict(state), 3).items()}
        for skey in ("stage:phi_stream", "stage:fused_two"):
            tgt = WT.with_tuning({skey: (("plane_block", 4),)})
            out = prog.compile(tgt, grid_shape=GRID).run(dict(state), 3)
            for k in ref:
                np.testing.assert_array_equal(
                    ref[k], np.asarray(out[k]),
                    err_msg=f"{skey} diverges on field {k!r}")

    def test_per_stage_autotune_round_trips_nested_tuning(self, tmp_path):
        skey = "pallas_windowed_interpret[stage:fused_two{plane_block=4}]"
        timer = ScriptedTimer({"stage:fused_two{plane_block=4}": 0.01},
                              default=1.0)
        tuned, rep = tdp.autotune(
            fused_prog("two_launch"), WT, lb_state(), timer=timer,
            executors=["pallas_windowed"], per_stage=True,
            cache_dir=str(tmp_path), reps=1, warmup=0)
        assert rep.best.label == skey
        assert dict(tuned.tuning)["stage:fused_two"] == \
            (("plane_block", 4),)
        # warm replay restores the nested choice exactly
        timer2 = ScriptedTimer({}, default=1.0)
        tuned2, rep2 = tdp.autotune(
            fused_prog("two_launch"), WT, lb_state(), timer=timer2,
            executors=["pallas_windowed"], per_stage=True,
            cache_dir=str(tmp_path), reps=1, warmup=0)
        assert rep2.cache_hit and timer2.calls == []
        assert dict(tuned2.tuning)["stage:fused_two"] == \
            (("plane_block", 4),)
