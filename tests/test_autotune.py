"""``tdp.autotune`` — the Program-level tuner over ``Target.tuning``.

Deterministic throughout: measurement runs under an *injected fake
timer* (scripted per-candidate costs — the pluggable-timer contract), so
these tests assert selection logic, pruning, caching and correctness
decoupling without ever depending on wall-clock noise:

* **best-candidate selection** — argmin of the scripted medians, with
  the base target always measured as candidate 0 (tuned median ≤
  default median by construction);
* **space construction** — executor axis capability-checked, the
  ``plane_block`` divisor sweep, VMEM-infeasibility pruning;
* **cache** — miss measures + writes ``<cache_dir>/<key>.json``, hit
  replays the stored choice without calling the timer at all;
* **correctness decoupling** — 5-step LB trajectories are bit-identical
  under *every* candidate in a small space (xla vs tuned
  pallas_interpret / pallas_windowed_interpret), and
  ``check_identical=True`` prunes an executor that lies.
"""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro import tdp
from repro.core import Lattice, STENCIL_GRAD_6PT
from repro.core.autotune import cache_key
from repro.lb import programs as lbp
from repro.lb.params import LBParams

GRID = (8, 8, 8)
PARAMS = LBParams(A=0.125, B=0.125, kappa=0.02)
WT = tdp.Target("pallas_windowed", interpret=True)


def fused_prog(mode="two_launch"):
    return lbp.fused_program(
        mode, lbp.collision_consts(**PARAMS.as_kwargs()))


def lb_state(grid=GRID, seed=0):
    rng = np.random.default_rng(seed)
    f = jnp.asarray(0.05 * rng.normal(size=(19,) + grid) + 1 / 19.,
                    jnp.float32)
    g = jnp.asarray(0.05 * rng.normal(size=(19,) + grid), jnp.float32)
    return {"f": f, "g": g}


class ScriptedTimer:
    """Fake timer: cost per candidate label, call log kept."""

    def __init__(self, costs, default=1.0):
        self.costs = dict(costs)
        self.default = default
        self.calls = []

    def __call__(self, target, run):
        label = tdp.Candidate.of(target).label
        self.calls.append(label)
        for key, cost in self.costs.items():
            if key in label:
                return cost
        return self.default


@tdp.kernel(fields=[tdp.field(2)], out=2)
def double2(x):
    return 2.0 * x


@tdp.kernel(fields=[tdp.field(1, stencil=STENCIL_GRAD_6PT)], out=1)
def star_sum(p):
    acc = p[0, 0]
    for i in range(1, 7):
        acc = acc + p[i, 0]
    return acc[None]


# ---------------------------------------------------------------------------
# space construction
# ---------------------------------------------------------------------------

class TestSpace:
    def test_program_space_has_base_xla_and_divisor_sweep(self):
        cands, pruned = tdp.default_space(fused_prog(), WT, grid_shape=GRID)
        labels = [c.label for c in cands]
        assert labels[0] == "pallas_windowed_interpret"      # the base
        assert "xla" in labels
        pbs = [dict(c.tuning)["plane_block"] for c in cands
               if "plane_block" in dict(c.tuning)]
        assert pbs == [1, 2, 4, 8]                           # divisors of 8
        assert all(GRID[0] % p == 0 for p in pbs)
        assert pruned == []

    def test_vmem_limit_prunes_large_plane_blocks(self):
        cands, pruned = tdp.default_space(fused_prog(), WT, grid_shape=GRID,
                                          vmem_limit=1)
        assert all("plane_block" not in dict(c.tuning) for c in cands)
        assert pruned and all("vmem estimate" in why for _, why in pruned)

    def test_pointwise_spec_excludes_halo_extended_executors(self):
        x = jnp.ones((2, 32), jnp.float32)
        cands, pruned = tdp.default_space(
            double2, tdp.Target("xla"),
            executors=("xla", "pallas_windowed"))
        labels = [c.label for c in cands]
        assert "pallas_windowed" not in labels
        assert any("halo_extended" in why for _, why in pruned)
        del x

    def test_pointwise_pallas_axis_sweeps_declared_block_knobs(self):
        cands, _ = tdp.default_space(
            double2, tdp.Target("xla"),
            executors=("xla", "pallas_interpret"))
        knobs = {k for c in cands for k, _ in c.tuning}
        assert "block_f" in knobs                  # declared tunable
        assert "plane_block" not in knobs          # not on this executor

    def test_stencil_spec_plane_block_candidates(self):
        lat = Lattice((12, 4, 4))
        feasible, pruned = tdp.plane_block_candidates(star_sum, WT, lat)
        assert feasible == [1, 2, 3, 4, 6, 12]
        assert pruned == []
        feasible, pruned = tdp.plane_block_candidates(
            star_sum, WT, lat, vmem_limit=0)
        assert feasible == [] and len(pruned) == 6


# ---------------------------------------------------------------------------
# selection with a fake timer
# ---------------------------------------------------------------------------

class TestSelection:
    def test_best_candidate_wins(self, tmp_path):
        timer = ScriptedTimer({"plane_block=4": 0.01, "xla": 0.5},
                              default=1.0)
        tuned, report = tdp.autotune(
            fused_prog(), WT, lb_state(), timer=timer,
            cache_dir=str(tmp_path), reps=3, warmup=0, measure_steps=1)
        assert report.best.label == "pallas_windowed_interpret[plane_block=4]"
        assert tuned.backend == "pallas_windowed" and tuned.interpret
        assert tuned.tune("plane_block") == 4
        assert report.best_median_s == pytest.approx(0.01)
        assert report.default_median_s == pytest.approx(1.0)
        assert report.best_median_s <= report.default_median_s

    def test_base_target_always_candidate_zero(self, tmp_path):
        timer = ScriptedTimer({}, default=1.0)   # flat costs: base wins ties
        tuned, report = tdp.autotune(
            fused_prog(), WT, lb_state(), timer=timer,
            cache_dir=str(tmp_path), reps=1, warmup=0)
        assert report.results[0].candidate.label == \
            "pallas_windowed_interpret"
        assert tuned.executor == WT.executor

    def test_budget_keeps_base_and_prunes_tail(self, tmp_path):
        timer = ScriptedTimer({}, default=1.0)
        _, report = tdp.autotune(
            fused_prog(), WT, lb_state(), timer=timer,
            cache_dir=str(tmp_path), budget=2, reps=1, warmup=0)
        assert len(report.results) == 2
        assert report.results[0].candidate.label == \
            "pallas_windowed_interpret"
        assert any("over budget" in why for _, why in report.pruned)

    def test_explicit_space_of_targets(self, tmp_path):
        timer = ScriptedTimer({"xla": 0.1}, default=1.0)
        tuned, report = tdp.autotune(
            fused_prog(), WT, lb_state(), timer=timer,
            space=["xla", WT.with_tuning(plane_block=2)],
            cache_dir=str(tmp_path), reps=1, warmup=0)
        assert tuned.executor == "xla"
        # the base was prepended even though the space didn't name it
        assert report.results[0].candidate.label == \
            "pallas_windowed_interpret"

    def test_explicit_space_listing_base_elsewhere_keeps_it_first(
            self, tmp_path):
        """Candidate 0 is the base target even when the space lists it at
        a later index — the default-median baseline must be the base."""
        timer = ScriptedTimer({"xla": 0.1}, default=1.0)
        _, report = tdp.autotune(
            fused_prog(), WT, lb_state(), timer=timer,
            space=["xla", WT], cache_dir=str(tmp_path), reps=1, warmup=0)
        labels = [r.candidate.label for r in report.results]
        assert labels[0] == "pallas_windowed_interpret"
        assert labels.count("pallas_windowed_interpret") == 1
        assert report.default_median_s == pytest.approx(1.0)   # not xla's

    def test_program_autotune_convenience(self, tmp_path):
        timer = ScriptedTimer({}, default=1.0)
        tuned, report = fused_prog().autotune(
            WT, lb_state(), timer=timer, cache_dir=str(tmp_path),
            reps=1, warmup=0)
        assert isinstance(report, tdp.TuneReport)
        assert tuned.executor == WT.executor

    def test_unrunnable_candidate_is_pruned_not_fatal(self, tmp_path):
        calls = {"n": 0}

        def exploding(target, run):
            calls["n"] += 1
            if target.executor == "xla":
                raise RuntimeError("boom")
            return 1.0

        _, report = tdp.autotune(
            fused_prog(), WT, lb_state(), timer=exploding,
            cache_dir=str(tmp_path), reps=1, warmup=0)
        assert any("boom" in why for label, why in report.pruned
                   if label == "xla")
        assert all(r.candidate.label != "xla" for r in report.results)


# ---------------------------------------------------------------------------
# the on-disk cache
# ---------------------------------------------------------------------------

class TestCache:
    def test_miss_writes_then_hit_skips_measurement(self, tmp_path):
        timer = ScriptedTimer({"plane_block=2": 0.01}, default=1.0)
        prog = fused_prog()
        tuned1, rep1 = tdp.autotune(prog, WT, lb_state(), timer=timer,
                                    cache_dir=str(tmp_path), reps=1,
                                    warmup=0)
        assert not rep1.cache_hit
        path = os.path.join(str(tmp_path), f"{rep1.cache_key}.json")
        assert os.path.exists(path)
        n_calls = len(timer.calls)
        assert n_calls > 0

        tuned2, rep2 = tdp.autotune(prog, WT, lb_state(), timer=timer,
                                    cache_dir=str(tmp_path), reps=1,
                                    warmup=0)
        assert rep2.cache_hit
        assert len(timer.calls) == n_calls          # no re-measurement
        assert tuned2 == tuned1
        assert rep2.best == rep1.best

    def test_cache_key_discriminates_grid_backend_and_graph(self):
        prog = fused_prog()
        k = cache_key(prog, WT, (8, 8, 8))
        assert k != cache_key(prog, WT, (16, 8, 8))
        assert k != cache_key(prog, tdp.Target("xla"), (8, 8, 8))
        assert k != cache_key(fused_prog("one_launch"), WT, (8, 8, 8))
        # interpreter-measured tuning must never answer for compiled runs
        assert k != cache_key(prog, tdp.Target("pallas_windowed"),
                              (8, 8, 8))
        # stable across calls (no PYTHONHASHSEED dependence)
        assert k == cache_key(fused_prog(), WT, (8, 8, 8))

    def test_corrupt_cache_entry_is_a_miss(self, tmp_path):
        timer = ScriptedTimer({}, default=1.0)
        prog = fused_prog()
        _, rep = tdp.autotune(prog, WT, lb_state(), timer=timer,
                              cache_dir=str(tmp_path), reps=1, warmup=0)
        path = os.path.join(str(tmp_path), f"{rep.cache_key}.json")
        with open(path, "w") as fh:
            fh.write("{not json")
        _, rep2 = tdp.autotune(prog, WT, lb_state(), timer=timer,
                               cache_dir=str(tmp_path), reps=1, warmup=0)
        assert not rep2.cache_hit                   # re-measured
        with open(path) as fh:
            assert json.load(fh)["cache_key"] == rep.cache_key

    def test_interrupted_write_preserves_previous_entry(self, tmp_path,
                                                        monkeypatch):
        """A writer killed mid-``json.dump`` must not clobber the existing
        cache entry: the dump goes to a tempfile and only a completed one
        is ``os.replace``d over the real path."""
        import importlib
        at = importlib.import_module("repro.core.autotune")

        timer = ScriptedTimer({"plane_block=2": 0.01}, default=1.0)
        prog = fused_prog()
        _, rep1 = tdp.autotune(prog, WT, lb_state(), timer=timer,
                               cache_dir=str(tmp_path), reps=1, warmup=0)
        path = os.path.join(str(tmp_path), f"{rep1.cache_key}.json")
        with open(path) as fh:
            before = fh.read()

        real_dump = json.dump

        def dying_dump(obj, fh, **kw):
            fh.write('{"cache_key": "half-writ')    # partial bytes...
            fh.flush()
            raise KeyboardInterrupt("killed mid-write")   # ...then death

        monkeypatch.setattr(at.json, "dump", dying_dump)
        rep_fake = at.TuneReport.from_dict(rep1.as_dict(), cache_hit=False)
        with pytest.raises(KeyboardInterrupt):
            at.store_cached(str(tmp_path), rep_fake)
        monkeypatch.setattr(at.json, "dump", real_dump)

        with open(path) as fh:
            assert fh.read() == before          # old entry intact
        assert json.loads(before)["cache_key"] == rep1.cache_key
        # no orphaned tempfiles left behind
        leftovers = [n for n in os.listdir(str(tmp_path))
                     if n.endswith(".tmp")]
        assert leftovers == []
        # and the entry still replays as a hit
        _, rep2 = tdp.autotune(prog, WT, lb_state(), timer=timer,
                               cache_dir=str(tmp_path), reps=1, warmup=0)
        assert rep2.cache_hit

    def test_concurrent_writers_leave_valid_entry(self, tmp_path):
        """N threads racing ``store_cached`` on the same key: the final
        file is one complete JSON document (some writer's replace wins
        whole — never an interleaving)."""
        import importlib
        import threading

        at = importlib.import_module("repro.core.autotune")

        timer = ScriptedTimer({}, default=1.0)
        _, rep = tdp.autotune(fused_prog(), WT, lb_state(), timer=timer,
                              cache_dir=str(tmp_path), reps=1, warmup=0)
        path = os.path.join(str(tmp_path), f"{rep.cache_key}.json")
        errs = []

        def write(i):
            try:
                r = at.TuneReport.from_dict(rep.as_dict(), cache_hit=False)
                for _ in range(20):
                    at.store_cached(str(tmp_path), r)
            except Exception as e:       # pragma: no cover - failure path
                errs.append(e)

        threads = [threading.Thread(target=write, args=(i,))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert errs == []
        with open(path) as fh:
            assert json.load(fh)["cache_key"] == rep.cache_key
        assert not [n for n in os.listdir(str(tmp_path))
                    if n.endswith(".tmp")]

    def test_cache_dir_none_disables(self, tmp_path):
        timer = ScriptedTimer({}, default=1.0)
        _, rep = tdp.autotune(fused_prog(), WT, lb_state(), timer=timer,
                              cache_dir=None, reps=1, warmup=0)
        assert not rep.cache_hit
        assert os.listdir(str(tmp_path)) == []

    def test_report_round_trips_through_json(self, tmp_path):
        timer = ScriptedTimer({"xla": 0.25}, default=1.0)
        _, rep = tdp.autotune(fused_prog(), WT, lb_state(), timer=timer,
                              cache_dir=str(tmp_path), reps=2, warmup=0)
        rebuilt = tdp.TuneReport.from_dict(rep.as_dict(), cache_hit=True)
        assert rebuilt.best == rep.best
        assert rebuilt.results == rep.results
        assert rebuilt.cache_key == rep.cache_key
        assert rebuilt.cache_hit


# ---------------------------------------------------------------------------
# correctness is decoupled from tuning
# ---------------------------------------------------------------------------

class TestCorrectnessDecoupling:
    @pytest.mark.parametrize("mode", ["one_launch", "two_launch"])
    def test_five_step_trajectories_bit_identical_under_all_candidates(
            self, mode):
        """Every candidate in the small space — xla and the tuned
        pallas_interpret / pallas_windowed_interpret variants — steps the
        LB program to bit-identical 5-step trajectories."""
        prog = fused_prog(mode)
        state = lb_state()
        space = [
            tdp.Target("xla"),
            tdp.Target("pallas_interpret"),
            WT,                                       # plane_block default
            WT.with_tuning(plane_block=2),
            WT.with_tuning(plane_block=4),
        ]
        ref = None
        for tgt in space:
            exe = prog.compile(tgt, grid_shape=GRID)
            out = exe.run(dict(state), 5)
            got = {k: np.asarray(v) for k, v in out.items()}
            if ref is None:
                ref = got
                continue
            for k in ref:
                np.testing.assert_array_equal(
                    ref[k], got[k],
                    err_msg=f"{tgt} diverges from xla on field {k!r}")

    def test_check_identical_accepts_honest_candidates(self, tmp_path):
        timer = ScriptedTimer({}, default=1.0)
        _, rep = tdp.autotune(
            fused_prog(), WT, lb_state(), timer=timer,
            cache_dir=str(tmp_path), reps=1, warmup=0, measure_steps=2,
            check_identical=True)
        # xla and every feasible plane_block variant all survive
        assert {r.candidate.label for r in rep.results} >= {
            "pallas_windowed_interpret", "xla"}
        assert not any("bit-identical" in why for _, why in rep.pruned)

    def test_check_identical_prunes_a_lying_executor(self, tmp_path):
        def lying(plan, prepared):
            outs = tdp.xla_executor(plan, prepared)
            return tuple(o + 1e-3 for o in outs)

        tdp.register_executor("lying_xla", lying)
        try:
            timer = ScriptedTimer({"lying_xla": 0.001}, default=1.0)
            tuned, rep = tdp.autotune(
                fused_prog(), tdp.Target("xla"), lb_state(), timer=timer,
                space=[tdp.Target("lying_xla")], cache_dir=str(tmp_path),
                reps=1, warmup=0, check_identical=True)
            assert any("bit-identical" in why for label, why in rep.pruned
                       if label == "lying_xla")
            assert tuned.executor == "xla"      # cheapest honest candidate
        finally:
            tdp.unregister_executor("lying_xla")
