"""Synthetic data pipeline: determinism, position ownership, learnability."""
import numpy as np
import pytest

from repro.data import SyntheticConfig, batch_for_step
from repro.data.synthetic import _successor_table


CFG = SyntheticConfig(vocab_size=100, seq_len=64, global_batch=8, seed=11)


class TestDeterminism:
    def test_same_step_same_batch(self):
        a = batch_for_step(CFG, 5)
        b = batch_for_step(CFG, 5)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_different_steps_differ(self):
        a = batch_for_step(CFG, 5)
        b = batch_for_step(CFG, 6)
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_rows_owned_by_position(self):
        """Host slicing must reproduce the same global rows — the elastic
        re-meshing guarantee (DESIGN.md §6)."""
        full = batch_for_step(CFG, 9)
        for lo, hi in ((0, 2), (3, 7), (6, 8)):
            part = batch_for_step(CFG, 9, lo=lo, hi=hi)
            np.testing.assert_array_equal(full["tokens"][lo:hi],
                                          part["tokens"])

    def test_labels_are_next_tokens(self):
        b = batch_for_step(CFG, 0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


class TestLearnability:
    def test_bigram_structure(self):
        """Every transition obeys the seed's successor table — the stream
        has ~log2(branching) bits/token, so CE can fall well below log V."""
        table = _successor_table(CFG)
        b = batch_for_step(CFG, 3)
        seq = np.concatenate([b["tokens"], b["labels"][:, -1:]], axis=1)
        for row in seq[:4]:
            for t in range(len(row) - 1):
                assert row[t + 1] in table[row[t]]

    def test_token_range(self):
        b = batch_for_step(CFG, 2)
        assert b["tokens"].min() >= 0
        assert b["tokens"].max() < CFG.vocab_size
