"""Lattice-Boltzmann application tests: physics + implementation equality.

The paper's motivating application.  Conservation laws are the integration
oracle: BGK collision + streaming conserves total mass exactly and the
binary order parameter exactly; momentum is conserved up to the
free-energy forcing (which sums to ~0 over a periodic box).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.lb.params import LBParams
from repro.lb.sim import BinaryFluidSim
from repro.lb import baseline, stencil
from repro.kernels.lb_collision import CV, NVEL, WEIGHTS


class TestStencil:
    def test_stream_conserves_and_shifts(self, rng):
        f = jnp.asarray(rng.normal(size=(NVEL, 4, 4, 4)), jnp.float32)
        fs = stencil.stream(f)
        # streaming is an exact permutation — sum in f64 so the assertion
        # is not at the mercy of float32 reduction order
        np.testing.assert_allclose(np.asarray(fs, np.float64).sum(),
                                   np.asarray(f, np.float64).sum(),
                                   rtol=1e-12)
        # q=0 is the rest particle: unmoved
        np.testing.assert_array_equal(fs[0], f[0])
        # each q shifted by its velocity (bit-exact: a gather, no math)
        for q in (1, 5, 10):
            want = np.roll(np.asarray(f[q]), shift=tuple(int(c) for c in CV[q]),
                           axis=(0, 1, 2))
            np.testing.assert_array_equal(np.asarray(fs[q]), want)

    def test_gradients_of_linear_field(self):
        """∇φ of a linear ramp is the slope; ∇²φ is 0 (periodic interior)."""
        x = np.arange(8.0)
        phi = jnp.asarray(np.broadcast_to(
            np.sin(2 * np.pi * x / 8)[:, None, None], (8, 8, 8)), jnp.float32)
        grad, del2 = stencil.gradients(phi)
        # numerical vs analytic derivative of sin
        want = (2 * np.pi / 8) * np.cos(2 * np.pi * x / 8)
        got = np.asarray(grad[0, :, 4, 4])
        # 2nd-order central difference of sin has a known sinc prefactor
        pref = np.sin(2 * np.pi / 8) / (2 * np.pi / 8)
        np.testing.assert_allclose(got, pref * want, rtol=1e-4, atol=1e-5)
        assert abs(float(grad[1].sum())) < 1e-3  # no y-gradient


class TestConservation:
    @pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
    def test_mass_and_phi_conserved(self, backend):
        sim = BinaryFluidSim((8, 8, 8), backend=backend, vvl=64)
        st = sim.init_spinodal(seed=1, noise=0.05)
        obs0 = sim.observables(st)
        st = sim.step(st, 10)
        obs1 = sim.observables(st)
        assert not obs1["nan"]
        np.testing.assert_allclose(obs1["mass"], obs0["mass"], rtol=1e-5)
        np.testing.assert_allclose(obs1["phi_total"], obs0["phi_total"],
                                   rtol=1e-5, atol=1e-4)

    def test_momentum_near_zero(self):
        """Periodic quench at rest: net momentum stays ~0 (forcing sums 0)."""
        sim = BinaryFluidSim((8, 8, 8))
        st = sim.init_spinodal(seed=2)
        st = sim.step(st, 10)
        c = jnp.asarray(CV, jnp.float32)
        mom = jnp.einsum("qd,qxyz->d", c, st.f)
        assert float(jnp.abs(mom).max()) < 1e-2

    def test_spinodal_coarsens(self):
        """Phase separation: φ variance grows from a symmetric quench and
        domains approach φ=±1 (deep quench for CPU-friendly timescales)."""
        p = LBParams(A=0.125, B=0.125, kappa=0.02)
        sim = BinaryFluidSim((12, 12, 12), params=p)
        st = sim.init_spinodal(seed=3, noise=0.05)
        v0 = sim.observables(st)["phi_var"]
        st = sim.run_scanned(st, 200)
        obs = sim.observables(st)
        assert not obs["nan"]
        assert obs["phi_var"] > 50 * v0          # domains formed
        assert obs["phi_max"] > 0.5 and obs["phi_min"] < -0.5

    def test_droplet_interface(self):
        """tanh droplet stays a droplet (φ bounds don't blow up)."""
        sim = BinaryFluidSim((12, 12, 12))
        st = sim.init_droplet()
        st = sim.step(st, 20)
        obs = sim.observables(st)
        assert not obs["nan"]
        assert -1.2 < obs["phi_min"] < -0.5 and 0.5 < obs["phi_max"] < 1.2


class TestFusedStep:
    """The fused stream→gradient→collide launch is a drop-in for the
    4-launch unfused pipeline: identical trajectory, conservation intact."""

    def test_fused_matches_unfused_trajectory(self):
        p = LBParams(A=0.125, B=0.125, kappa=0.02)
        a = BinaryFluidSim((16, 16, 16), params=p)
        b = BinaryFluidSim((16, 16, 16), params=p, fused=True)
        st0 = a.init_spinodal(seed=3, noise=0.05)
        ua = a.step(st0, 10)
        ub = b.step(st0, 10)
        np.testing.assert_allclose(np.asarray(ua.f), np.asarray(ub.f),
                                   rtol=2e-4, atol=2e-5)
        np.testing.assert_allclose(np.asarray(ua.g), np.asarray(ub.g),
                                   rtol=2e-4, atol=2e-5)

    def test_two_launch_matches_one_launch_trajectory(self):
        """ROADMAP stencil-memory stage (a): the two-launch step (streamed-φ
        1-component intermediate instead of the 57-offset g gather) keeps
        the identical accumulation order — trajectories match bit-for-bit
        with the one-launch fused path."""
        p = LBParams(A=0.125, B=0.125, kappa=0.02)
        a = BinaryFluidSim((16, 16, 16), params=p, fused="one_launch")
        b = BinaryFluidSim((16, 16, 16), params=p, fused="two_launch")
        st0 = a.init_spinodal(seed=3, noise=0.05)
        ua = a.step(st0, 10)
        ub = b.step(st0, 10)
        np.testing.assert_array_equal(np.asarray(ua.f), np.asarray(ub.f))
        np.testing.assert_array_equal(np.asarray(ua.g), np.asarray(ub.g))

    def test_two_launch_matches_unfused_trajectory(self):
        p = LBParams(A=0.125, B=0.125, kappa=0.02)
        a = BinaryFluidSim((16, 16, 16), params=p)
        b = BinaryFluidSim((16, 16, 16), params=p, fused="two_launch")
        st0 = a.init_spinodal(seed=3, noise=0.05)
        ua = a.step(st0, 10)
        ub = b.step(st0, 10)
        np.testing.assert_allclose(np.asarray(ua.f), np.asarray(ub.f),
                                   rtol=2e-4, atol=2e-5)
        np.testing.assert_allclose(np.asarray(ua.g), np.asarray(ub.g),
                                   rtol=2e-4, atol=2e-5)

    @pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
    def test_two_launch_conserves(self, backend):
        sim = BinaryFluidSim((8, 8, 8), backend=backend, vvl=64,
                             fused="two_launch")
        st = sim.init_spinodal(seed=1, noise=0.05)
        obs0 = sim.observables(st)
        st = sim.step(st, 10)
        obs1 = sim.observables(st)
        assert not obs1["nan"]
        np.testing.assert_allclose(obs1["mass"], obs0["mass"], rtol=1e-5)
        np.testing.assert_allclose(obs1["phi_total"], obs0["phi_total"],
                                   rtol=1e-5, atol=1e-4)

    def test_fused_mode_validation(self):
        with pytest.raises(ValueError, match="fused"):
            BinaryFluidSim((8, 8, 8), fused="three_launch")

    def test_fused_scanned_matches_stepped(self):
        sim = BinaryFluidSim((8, 8, 8), fused=True)
        st = sim.init_spinodal(seed=4)
        a = sim.step(st, 6)
        b = sim.run_scanned(st, 6)
        np.testing.assert_allclose(a.f, b.f, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(a.g, b.g, rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("backend,vvl", [("xla", 128),
                                             ("pallas_interpret", 64)])
    def test_fused_conserves(self, backend, vvl):
        sim = BinaryFluidSim((8, 8, 8), backend=backend, vvl=vvl, fused=True)
        st = sim.init_spinodal(seed=1, noise=0.05)
        obs0 = sim.observables(st)
        st = sim.step(st, 10)
        obs1 = sim.observables(st)
        assert not obs1["nan"]
        np.testing.assert_allclose(obs1["mass"], obs0["mass"], rtol=1e-5)
        np.testing.assert_allclose(obs1["phi_total"], obs0["phi_total"],
                                   rtol=1e-5, atol=1e-4)


class TestBaselineEquivalence:
    """Paper Fig. 1: "original" AoS innermost-loop code vs targetDP —
    identical numerics, different execution structure."""

    def test_original_matches_targetdp(self, rng):
        """AoS 'original code' path == SoA targetDP path after transpose."""
        p = LBParams()
        n = 128
        f = jnp.asarray(0.05 * rng.normal(size=(19, n)) + 1 / 19., jnp.float32)
        g = jnp.asarray(0.05 * rng.normal(size=(19, n)), jnp.float32)
        phi = g.sum(0, keepdims=True)
        gp = jnp.asarray(0.01 * rng.normal(size=(3, n)), jnp.float32)
        d2 = jnp.asarray(0.01 * rng.normal(size=(1, n)), jnp.float32)
        fo_b, go_b = baseline.collide_aos(f.T, g.T, phi[0], gp.T, d2[0], p)
        from repro.kernels import ops
        fo_t, go_t = ops.lb_collision(f, g, phi, gp, d2, **p.as_kwargs())
        np.testing.assert_allclose(fo_b.T, fo_t, rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(go_b.T, go_t, rtol=2e-5, atol=2e-5)

    def test_stream_aos_matches_soa(self, rng):
        f = jnp.asarray(rng.normal(size=(NVEL, 4, 4, 4)), jnp.float32)
        a = stencil.stream(f)
        b = baseline.stream_aos(jnp.moveaxis(f, 0, -1))
        np.testing.assert_allclose(jnp.moveaxis(b, -1, 0), a, rtol=1e-6)

    def test_scanned_run_matches_stepped(self):
        sim = BinaryFluidSim((8, 8, 8))
        st = sim.init_spinodal(seed=4)
        a = sim.step(st, 5)
        b = sim.run_scanned(st, 5)
        np.testing.assert_allclose(a.f, b.f, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(a.g, b.g, rtol=1e-5, atol=1e-6)
