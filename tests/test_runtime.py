"""Runtime: trainer loop, fault injection, restart continuation, monitors."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import SyntheticConfig
from repro.models.config import AttnConfig, ModelConfig, repeat_program
from repro.optim import AdamWConfig
from repro.runtime import (Heartbeat, StragglerMonitor, Trainer,
                           TrainerConfig, TrainHParams)
from repro.runtime.monitor import PeerFailure

TINY = ModelConfig(
    name="tiny", d_model=32, n_layers=2, vocab_size=64, d_ff=64,
    layer_program=repeat_program(("attn",), 2),
    attn=AttnConfig(2, 2, 16))

DATA = SyntheticConfig(vocab_size=64, seq_len=16, global_batch=4, seed=1)


def make_trainer(tmp, **kw):
    hp = TrainHParams(grad_accum=kw.pop("grad_accum", 1), warmup_steps=2,
                      total_steps=100)
    tc = TrainerConfig(ckpt_dir=str(tmp), ckpt_every=kw.pop("ckpt_every", 5),
                       log_every=100, hb_dir=kw.pop("hb_dir", None),
                       log=lambda *_: None, **kw)
    return Trainer(TINY, None, DATA, AdamWConfig(), hp, tc)


class TestTrainerLoop:
    def test_loss_decreases(self, tmp_path):
        tr = make_trainer(tmp_path / "a", ckpt_every=1000)
        losses = []
        orig = tr._jit_step

        def spy(p, o, b):
            out = orig(p, o, b)
            losses.append(float(out[2]["loss"]))
            return out

        tr._jit_step = spy
        tr.train_steps(40)
        assert np.mean(losses[-5:]) < np.mean(losses[:5])

    def test_grad_accum_equivalence(self, tmp_path):
        """accum=2 over the same global batch ≈ accum=1 (same data)."""
        t1 = make_trainer(tmp_path / "g1", ckpt_every=1000, grad_accum=1)
        t2 = make_trainer(tmp_path / "g2", ckpt_every=1000, grad_accum=2)
        t2.params = jax.tree.map(jnp.copy, t1.params)
        t2.opt_state = jax.tree.map(jnp.copy, t1.opt_state)
        t1.train_steps(3)
        t2.train_steps(3)
        for a, b in zip(jax.tree.leaves(t1.params),
                        jax.tree.leaves(t2.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-3, atol=2e-4)

    def test_restart_continuation_bit_exact(self, tmp_path):
        """Kill after step 10, restart from checkpoint, reach step 20 with
        the exact params of an uninterrupted run (stateless data + ckpt)."""
        ref = make_trainer(tmp_path / "ref", ckpt_every=10)
        ref.run(20)
        a = make_trainer(tmp_path / "ab", ckpt_every=10)
        a.train_steps(10)           # checkpoint written at 10
        a.ckpt.wait()
        b = make_trainer(tmp_path / "ab", ckpt_every=10)  # fresh process
        b.run(20)
        for x, y in zip(jax.tree.leaves(ref.params),
                        jax.tree.leaves(b.params)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_peer_failure_triggers_restart(self, tmp_path):
        hb_dir = str(tmp_path / "hb")
        tr = make_trainer(tmp_path / "pf", ckpt_every=5, hb_dir=hb_dir)
        # a dead peer: stale heartbeat from "host 7"
        dead = Heartbeat(hb_dir, host_id=7, timeout_s=0.05)
        dead.beat(0)
        tr.hb.timeout_s = 0.05
        time.sleep(0.1)
        calls = {"n": 0}

        def resurrect(_):
            # after the failure fires once, revive the peer so the restart
            # body can finish
            calls["n"] += 1
            if calls["n"] >= 1:
                dead.beat(calls["n"])

        with pytest.raises(PeerFailure):
            tr.train_steps(10)
        # restart loop handles it end-to-end
        tr2 = make_trainer(tmp_path / "pf", ckpt_every=5, hb_dir=hb_dir)
        tr2.hb.timeout_s = 1000.0     # peer considered alive again
        tr2.run(12)
        assert tr2.step == 12


class TestMonitors:
    def test_straggler_flags_slow_step(self):
        logs = []
        mon = StragglerMonitor(threshold=2.0, warmup=0,
                               log=lambda m: logs.append(m))
        mon.record(0, 0.1)      # seeds EWMA
        for i in range(1, 6):
            assert not mon.record(i, 0.1)
        assert mon.record(6, 0.5)          # 5× EWMA → flagged
        assert len(mon.flagged) == 1 and "rebalance" in logs[0]

    def test_straggler_warmup_skipped(self):
        mon = StragglerMonitor(warmup=3, log=lambda m: None)
        assert not mon.record(0, 99.0)     # compile step ignored
        assert not mon.record(1, 99.0)

    def test_heartbeat_cycle(self, tmp_path):
        clock = {"t": 0.0}
        hb0 = Heartbeat(str(tmp_path), 0, timeout_s=5,
                        clock=lambda: clock["t"])
        hb1 = Heartbeat(str(tmp_path), 1, timeout_s=5,
                        clock=lambda: clock["t"])
        hb0.beat(1)
        hb1.beat(1)
        assert hb0.dead_peers() == []
        clock["t"] = 10.0
        hb0.beat(2)                        # host 0 alive, host 1 stale
        assert hb0.dead_peers() == [1]
        with pytest.raises(PeerFailure):
            hb0.check()


class TestServeSteps:
    def test_greedy_vs_sampled(self):
        from repro.runtime.steps import sample_logits
        logits = jnp.asarray([[[-1.0, 5.0, 0.0, 2.0]]])
        tok = sample_logits(logits, jax.random.PRNGKey(0), temperature=0.0)
        assert int(tok[0, 0]) == 1
        tok2 = sample_logits(logits, jax.random.PRNGKey(0),
                             temperature=1.0, top_k=2)
        assert int(tok2[0, 0]) in (1, 3)
