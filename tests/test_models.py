"""Model substrate tests: all 10 assigned archs (reduced configs) +
implementation-equivalence pins (MoE paths, MLA absorbed decode, chunked
attention, prefill↔decode consistency).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as C
from repro.models import lm, params as params_lib
from repro.models.config import (AttnConfig, MLAConfig, ModelConfig,
                                 MoEConfig, plan_layer_groups,
                                 repeat_program)
from repro.models.context import ExecContext

CTX = ExecContext()


def _batch_for(cfg, b=2, s=16, rng=None):
    rng = rng or np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    batch["labels"] = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    if cfg.is_encdec:
        batch["audio_embed"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder.n_frames, cfg.d_model)),
            jnp.float32)
    if cfg.vision_stub:
        slot = -np.ones((b, s), np.int32)
        slot[:, :4] = np.arange(4)
        batch["vision_embed"] = jnp.asarray(
            rng.normal(size=(b, 8, cfg.d_model)), jnp.float32)
        batch["vision_slot"] = jnp.asarray(slot)
    if cfg.pos_embed == "mrope":
        batch["positions3"] = jnp.tile(
            jnp.arange(s, dtype=jnp.int32)[None, None], (3, b, 1))
    return batch


@pytest.mark.parametrize("arch", C.ARCHS)
class TestArchSmokes:
    """Per-arch reduced-config smoke: one train step + prefill + decode on
    CPU, asserting shapes and finiteness (the brief's required smokes)."""

    def test_train_step_runs(self, arch):
        cfg = C.get_smoke(arch)
        params, _ = params_lib.init_params(cfg, jax.random.PRNGKey(0))
        batch = _batch_for(cfg)

        def loss(p):
            return lm.loss_fn(p, batch, cfg, CTX)[0]

        l0, grads = jax.value_and_grad(loss)(params)
        assert jnp.isfinite(l0)
        gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
                    for g in jax.tree.leaves(grads))
        assert np.isfinite(gnorm) and gnorm > 0
        # one SGD step lowers nothing catastrophically
        params2 = jax.tree.map(lambda p, g: p - 1e-2 * g, params, grads)
        l1 = loss(params2)
        assert jnp.isfinite(l1)

    def test_prefill_decode_consistency(self, arch):
        """Greedy decode after prefill == teacher-forced forward argmax."""
        cfg = C.get_smoke(arch)
        params, _ = params_lib.init_params(cfg, jax.random.PRNGKey(0))
        b, s = 2, 12
        batch = _batch_for(cfg, b, s)
        # full forward logits at the last position
        h, _ = lm.forward_hidden(params, batch, cfg, CTX)
        from repro.models import layers
        full_logits = layers.logits_from_hidden(params, h[:, -1:], cfg)
        logits, caches, _ = lm.prefill(params, batch, cfg, CTX)
        np.testing.assert_allclose(np.asarray(logits, np.float32),
                                   np.asarray(full_logits, np.float32),
                                   rtol=2e-4, atol=2e-4)
        # decode one token
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        pos3 = (jnp.full((3, b, 1), s, jnp.int32)
                if cfg.pos_embed == "mrope" else None)
        lg2, _ = lm.decode_step(params, tok, caches, s, cfg, CTX,
                                positions3=pos3)
        assert lg2.shape == (b, 1, cfg.padded_vocab)
        assert bool(jnp.isfinite(lg2).all())

    def test_config_matches_brief(self, arch):
        """Full config numbers must match the assignment brief exactly."""
        brief = {
            "deepseek_v3_671b": dict(n_layers=61, d_model=7168, heads=128,
                                     kv=128, vocab=129280, experts=256,
                                     top_k=8),
            "granite_moe_1b_a400m": dict(n_layers=24, d_model=1024, heads=16,
                                         kv=8, vocab=49155, experts=32,
                                         top_k=8),
            "gemma3_27b": dict(n_layers=62, d_model=5376, heads=32, kv=16,
                               d_ff=21504, vocab=262144),
            "nemotron_4_15b": dict(n_layers=32, d_model=6144, heads=48, kv=8,
                                   d_ff=24576, vocab=256000),
            "phi3_medium_14b": dict(n_layers=40, d_model=5120, heads=40,
                                    kv=10, d_ff=17920, vocab=100352),
            "gemma2_2b": dict(n_layers=26, d_model=2304, heads=8, kv=4,
                              d_ff=9216, vocab=256000),
            "zamba2_2p7b": dict(n_layers=54, d_model=2560, heads=32, kv=32,
                                d_ff=10240, vocab=32000, ssm_state=64),
            "falcon_mamba_7b": dict(n_layers=64, d_model=4096, vocab=65024,
                                    ssm_state=16),
            "whisper_medium": dict(n_layers=24, d_model=1024, heads=16,
                                   kv=16, d_ff=4096, vocab=51865),
            "qwen2_vl_2b": dict(n_layers=28, d_model=1536, heads=12, kv=2,
                                d_ff=8960, vocab=151936),
        }[arch]
        cfg = C.get_config(arch)
        assert cfg.n_layers == brief["n_layers"]
        assert cfg.d_model == brief["d_model"]
        assert cfg.vocab_size == brief["vocab"]
        if "heads" in brief:
            assert cfg.attn.n_heads == brief["heads"]
            assert cfg.attn.n_kv_heads == brief["kv"]
        if "d_ff" in brief:
            assert cfg.d_ff == brief["d_ff"]
        if "experts" in brief:
            assert cfg.moe.num_experts == brief["experts"]
            assert cfg.moe.top_k == brief["top_k"]
        if "ssm_state" in brief:
            assert cfg.ssm.d_state == brief["ssm_state"]


class TestShapeCells:
    def test_cell_count_is_40(self):
        cells = [(a, s, skip) for a in C.ARCHS
                 for s, skip in C.applicable_cells(a)]
        assert len(cells) == 40
        skipped = [c for c in cells if c[2]]
        assert len(skipped) == 6          # long_500k for pure full-attention
        assert {a for a, s, _ in skipped} == {
            "deepseek_v3_671b", "granite_moe_1b_a400m", "nemotron_4_15b",
            "phi3_medium_14b", "whisper_medium", "qwen2_vl_2b"}

    def test_input_specs_never_allocate(self):
        spec = C.input_specs("gemma2-2b", "decode_32k")
        leaves = jax.tree.leaves(spec)
        assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
        assert spec["token"].shape == (128, 1)

    def test_long500k_specs(self):
        spec = C.input_specs("falcon-mamba-7b", "long_500k")
        # SSM caches are seq-independent: tiny state despite 500k context
        total = sum(np.prod(l.shape) * l.dtype.itemsize
                    for l in jax.tree.leaves(spec["caches"]))
        assert total < 2 ** 30


class TestLayerProgram:
    def test_groups_cover_program(self):
        for arch in C.ARCHS:
            prog = C.get_config(arch).layer_program
            groups = plan_layer_groups(prog)
            rebuilt = []
            for unit, k in groups:
                rebuilt.extend(list(unit) * k)
            assert tuple(rebuilt) == prog, arch

    def test_periodic_detection(self):
        prog = repeat_program(("local",) * 5 + ("attn",), 62)
        groups = plan_layer_groups(prog)
        assert groups[0][1] >= 10  # 10 repeats of the 6-block unit


class TestEquivalences:
    def _moe_cfg(self, cf=8.0):
        return ModelConfig(
            name="m", d_model=64, n_layers=2, vocab_size=256, d_ff=128,
            layer_program=repeat_program(("attn_moe",), 2),
            attn=AttnConfig(4, 2, 16),
            moe=MoEConfig(num_experts=8, top_k=2, d_expert=32, num_shared=1,
                          capacity_factor=cf))

    def test_capacity_equals_ragged(self):
        """With generous capacity, the packed path is exactly dropless."""
        cfg = self._moe_cfg()
        params, _ = params_lib.init_params(cfg, jax.random.PRNGKey(0))
        batch = _batch_for(cfg)
        l1 = lm.loss_fn(params, batch, cfg, ExecContext(moe_impl="capacity"))[0]
        l2 = lm.loss_fn(params, batch, cfg, ExecContext(moe_impl="ragged"))[0]
        np.testing.assert_allclose(l1, l2, rtol=1e-5)

    def test_grouped_matmul_vjp(self, rng):
        from repro.models.moe import grouped_matmul
        E, T, D, F = 4, 24, 8, 6
        xs = jnp.asarray(rng.normal(size=(T, D)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(E, D, F)), jnp.float32)
        gs = jnp.array([6, 2, 10, 6])
        idx = np.repeat(np.arange(E), np.asarray(gs))

        def dense(xs, w):
            return jnp.einsum("td,tdf->tf", xs, w[idx])

        g1 = jax.grad(lambda a, b: (grouped_matmul(a, b, gs) ** 2).sum(),
                      argnums=(0, 1))(xs, w)
        g2 = jax.grad(lambda a, b: (dense(a, b) ** 2).sum(),
                      argnums=(0, 1))(xs, w)
        np.testing.assert_allclose(g1[0], g2[0], rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(g1[1], g2[1], rtol=1e-5, atol=1e-5)

    def test_mla_decode_matches_prefill_continuation(self):
        """Absorbed-latent decode == expanded-path full forward, token t+1."""
        cfg = C.get_smoke("deepseek_v3_671b")
        params, _ = params_lib.init_params(cfg, jax.random.PRNGKey(1))
        rng = np.random.default_rng(3)
        b, s = 2, 10
        toks = rng.integers(0, cfg.vocab_size, (b, s + 1))
        batch_s = {"tokens": jnp.asarray(toks[:, :s], jnp.int32)}
        batch_s1 = {"tokens": jnp.asarray(toks, jnp.int32)}
        # full forward over s+1 tokens: logits at the last position
        h, _ = lm.forward_hidden(params, batch_s1, cfg, CTX)
        from repro.models import layers
        want = layers.logits_from_hidden(params, h[:, -1:], cfg)
        # prefill s tokens then decode token s
        _, caches, _ = lm.prefill(params, batch_s, cfg, CTX)
        # grow cache by one slot to hold the decoded token
        def grow(c):
            if isinstance(c, dict):
                return {k: grow(v) for k, v in c.items()}
            if isinstance(c, list):
                return [grow(v) for v in c]
            return c
        from repro.runtime.steps import _pad_caches
        caches = _pad_caches(caches, cfg, s + 1)
        got, _ = lm.decode_step(
            params, jnp.asarray(toks[:, s:s + 1], jnp.int32), caches, s,
            cfg, CTX)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=5e-3, atol=5e-3)

    def test_sliding_window_decode_matches_full(self):
        """gemma2 smoke: decode with window masks == full forward."""
        cfg = C.get_smoke("gemma2_2b")
        params, _ = params_lib.init_params(cfg, jax.random.PRNGKey(2))
        rng = np.random.default_rng(5)
        b, s = 1, 14
        toks = rng.integers(0, cfg.vocab_size, (b, s + 1))
        h, _ = lm.forward_hidden(
            params, {"tokens": jnp.asarray(toks, jnp.int32)}, cfg, CTX)
        from repro.models import layers
        want = layers.logits_from_hidden(params, h[:, -1:], cfg)
        _, caches, _ = lm.prefill(
            params, {"tokens": jnp.asarray(toks[:, :s], jnp.int32)}, cfg, CTX)
        from repro.runtime.steps import _pad_caches
        caches = _pad_caches(caches, cfg, s + 1)
        got, _ = lm.decode_step(
            params, jnp.asarray(toks[:, s:s + 1], jnp.int32), caches, s,
            cfg, CTX)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=5e-3, atol=5e-3)

    def test_mamba_decode_matches_full(self):
        """falcon-mamba smoke: stepwise decode == full-sequence scan."""
        cfg = C.get_smoke("falcon_mamba_7b")
        params, _ = params_lib.init_params(cfg, jax.random.PRNGKey(4))
        rng = np.random.default_rng(6)
        b, s = 1, 10
        toks = rng.integers(0, cfg.vocab_size, (b, s + 1))
        h, _ = lm.forward_hidden(
            params, {"tokens": jnp.asarray(toks, jnp.int32)}, cfg, CTX)
        from repro.models import layers
        want = layers.logits_from_hidden(params, h[:, -1:], cfg)
        _, caches, _ = lm.prefill(
            params, {"tokens": jnp.asarray(toks[:, :s], jnp.int32)}, cfg, CTX)
        got, _ = lm.decode_step(
            params, jnp.asarray(toks[:, s:s + 1], jnp.int32), caches, s,
            cfg, CTX)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=5e-3, atol=5e-3)
