"""Checkpoint store: atomicity, integrity, retention, async, elasticity."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint,
                              verify_checkpoint)
from repro.optim import AdamWConfig, adamw_init


@pytest.fixture
def tree(rng):
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(64, 32)), jnp.float32),
                   "stack": jnp.asarray(rng.normal(size=(8, 16, 16)),
                                        jnp.bfloat16)},
        "step": jnp.asarray(7, jnp.int32),
    }


class TestRoundtrip:
    def test_basic(self, tmp_path, tree):
        save_checkpoint(str(tmp_path), 7, tree, extra={"foo": "bar"})
        got, extra, step = restore_checkpoint(str(tmp_path), tree,
                                              verify=True)
        assert step == 7 and extra["foo"] == "bar"
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(tree)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_quantized_opt_state_roundtrips(self, tmp_path):
        """QTensor (NamedTuple) leaves survive the manifest format."""
        params = {"w": jnp.ones((40, 8))}
        st = adamw_init(params, AdamWConfig(quantize_moments=True,
                                            quant_block=16))
        save_checkpoint(str(tmp_path), 1, st)
        got, _, _ = restore_checkpoint(str(tmp_path), st)
        np.testing.assert_array_equal(got["m"]["w"].codes, st["m"]["w"].codes)

    def test_sharded_files_concatenate(self, tmp_path, rng):
        big = {"x": jnp.asarray(rng.normal(size=(1024, 512)), jnp.float32)}
        d = save_checkpoint(str(tmp_path), 3, big, nshards=4)
        files = [f for f in os.listdir(d) if f.endswith(".npy")]
        assert len(files) == 4
        got, _, _ = restore_checkpoint(str(tmp_path), big)
        np.testing.assert_array_equal(got["x"], big["x"])


class TestPythonLeaves:
    def test_python_scalar_and_str_leaves_roundtrip(self, tmp_path):
        """Fleet ticket metadata — a python step counter, a bucket-id
        string, a flag — round-trips type-faithfully (manifest "py"
        entries, not .npy files coerced through np.asarray)."""
        tree = {"step": 17, "bucket": "lb_step@8x8x8#0", "resumable": True,
                "lr": 2.5e-4, "x": jnp.arange(3.0),
                "rng": jax.random.PRNGKey(7)}
        save_checkpoint(str(tmp_path), 1, tree)
        like = {"step": 0, "bucket": "", "resumable": False, "lr": 0.0,
                "x": 0.0, "rng": 0}
        got, _, _ = restore_checkpoint(str(tmp_path), like, verify=True)
        assert got["step"] == 17 and type(got["step"]) is int
        assert got["bucket"] == "lb_step@8x8x8#0" and \
            type(got["bucket"]) is str
        assert got["resumable"] is True
        assert got["lr"] == 2.5e-4 and type(got["lr"]) is float
        np.testing.assert_array_equal(np.asarray(got["x"]),
                                      np.arange(3.0))
        np.testing.assert_array_equal(np.asarray(got["rng"]),
                                      np.asarray(jax.random.PRNGKey(7)))

    def test_verify_tolerates_py_entries(self, tmp_path):
        save_checkpoint(str(tmp_path), 2, {"tag": "abc", "n": 3})
        d = os.path.join(str(tmp_path), "step_000000000002")
        assert verify_checkpoint(d)

    def test_manager_preserves_py_leaves(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(4, {"step": 4, "w": jnp.ones(2)}, blocking=True)
        got, _, _ = mgr.restore_latest({"step": 0, "w": 0.0})
        assert got["step"] == 4 and type(got["step"]) is int

    def test_midflight_program_state_roundtrip(self, tmp_path):
        """A mid-flight fleet member (ProgramState + metadata) restores
        exactly — the FleetDriver durability contract's storage half."""
        from repro import tdp
        rng = np.random.default_rng(0)
        state = tdp.ProgramState(
            {"f": jnp.asarray(rng.normal(size=(19, 4, 4, 4)),
                              jnp.float32),
             "g": jnp.asarray(rng.normal(size=(19, 4, 4, 4)),
                              jnp.float32)})
        tree = {"state": state, "step": 12, "bucket": "lb@4x4x4#0",
                "rng": jax.random.PRNGKey(3)}
        save_checkpoint(str(tmp_path), 12, tree)
        like = {"state": tdp.ProgramState({"f": 0.0, "g": 0.0}),
                "step": 0, "bucket": "", "rng": 0}
        got, _, _ = restore_checkpoint(str(tmp_path), like, verify=True)
        assert isinstance(got["state"], tdp.ProgramState)
        assert got["state"].fields == ("f", "g")
        for f in ("f", "g"):
            np.testing.assert_array_equal(np.asarray(got["state"][f]),
                                          np.asarray(state[f]))
        assert got["step"] == 12 and got["bucket"] == "lb@4x4x4#0"


class TestFaultTolerance:
    def test_atomic_no_partial_visible(self, tmp_path, tree):
        """A leftover .tmp dir is never picked up as a checkpoint."""
        save_checkpoint(str(tmp_path), 5, tree)
        fake = os.path.join(str(tmp_path), "step_000000000009.tmp")
        os.makedirs(fake)
        assert latest_step(str(tmp_path)) == 5

    def test_corruption_detected(self, tmp_path, tree):
        d = save_checkpoint(str(tmp_path), 5, tree)
        assert verify_checkpoint(d)
        npy = [f for f in os.listdir(d) if f.endswith(".npy")][0]
        with open(os.path.join(d, npy), "r+b") as f:
            f.seek(200)
            f.write(b"\xde\xad")
        assert not verify_checkpoint(d)
        with pytest.raises(IOError):
            restore_checkpoint(str(tmp_path), tree, verify=True)

    def test_missing_leaf_detected(self, tmp_path, tree):
        save_checkpoint(str(tmp_path), 5, tree)
        bigger = dict(tree)
        bigger["new_leaf"] = jnp.zeros((3,))
        with pytest.raises(KeyError):
            restore_checkpoint(str(tmp_path), bigger)

    def test_retention_and_latest(self, tmp_path, tree):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, tree, blocking=True)
        steps = sorted(int(d[5:]) for d in os.listdir(str(tmp_path))
                       if d.startswith("step_"))
        assert steps == [3, 4]
        assert latest_step(str(tmp_path)) == 4

    def test_async_save_overlaps(self, tmp_path, tree):
        mgr = CheckpointManager(str(tmp_path), keep=5)
        mgr.save(1, tree)          # background thread
        mgr.save(2, tree)          # joins the previous save first
        mgr.wait()
        assert latest_step(str(tmp_path)) == 2
        assert verify_checkpoint(os.path.join(str(tmp_path),
                                              "step_000000000002"))


class TestElasticRestore:
    def test_restore_onto_different_sharding(self, tmp_path, tree):
        """Written replicated, restored with a 1×1 mesh NamedSharding —
        the layout decision is restore-time, not save-time."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        save_checkpoint(str(tmp_path), 1, tree)
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh((1, 1), ("data", "model"))
        sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
        got, _, _ = restore_checkpoint(str(tmp_path), tree, shardings=sh)
        assert got["params"]["w"].sharding.mesh.shape["data"] == 1
        np.testing.assert_array_equal(got["params"]["w"],
                                      tree["params"]["w"])
