"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro import core as tdp
from repro.core import Field, Lattice
from repro.kernels import ref
from repro.models.config import plan_layer_groups, repeat_program, BLOCK_TYPES
from repro.optim import dequantize_blockwise, quantize_blockwise

SET = settings(max_examples=25, deadline=None)


@st.composite
def lattice_and_vvl(draw):
    dims = draw(st.lists(st.integers(2, 9), min_size=1, max_size=3))
    vvl = draw(st.sampled_from([4, 8, 16, 32]))
    return Lattice(tuple(dims)), vvl


class TestTdpProperties:
    @SET
    @given(lattice_and_vvl(), st.floats(-3, 3))
    def test_launch_padding_never_pollutes(self, lat_vvl, a):
        """Padding sites must never leak into outputs for ANY lattice/VVL."""
        lat, vvl = lat_vvl

        @tdp.site_kernel
        def affine(x, a=1.0):
            return a * x + 1.0

        rng = np.random.default_rng(lat.nsites)
        x = jnp.asarray(rng.normal(size=(2, lat.nsites)), jnp.float32)
        y = tdp.launch(affine, lat, [x], consts={"a": a}, vvl=vvl)
        np.testing.assert_allclose(y, a * x + 1.0, rtol=1e-5, atol=1e-5)

    @SET
    @given(lattice_and_vvl())
    def test_reduce_sum_matches_numpy(self, lat_vvl):
        lat, vvl = lat_vvl

        @tdp.site_kernel
        def ident(x):
            return x

        rng = np.random.default_rng(lat.nsites + 1)
        x = jnp.asarray(rng.normal(size=(3, lat.nsites)), jnp.float32)
        got = tdp.reduce(ident, lat, [x], op="sum", vvl=vvl)
        np.testing.assert_allclose(got, np.asarray(x).sum(-1), rtol=1e-4)

    @SET
    @given(st.integers(1, 64), st.integers(1, 5))
    def test_masked_copy_partition(self, nsites, ncomp):
        """Masked copy of M ∪ masked copy of ¬M == full copy."""
        from repro.core import (copy_from_target_masked, copy_to_target)
        lat = Lattice((nsites,))
        rng = np.random.default_rng(nsites * ncomp)
        f = Field(lat, ncomp, np.float32)
        f.data[...] = rng.normal(size=f.array_shape)
        t = copy_to_target(f)
        mask = rng.random(nsites) < 0.5
        a = Field(lat, ncomp, np.float32)
        copy_from_target_masked(t, mask, a)
        copy_from_target_masked(t, ~mask, a)
        np.testing.assert_allclose(a.data, f.data, rtol=1e-6)


class TestLayoutProperties:
    """SoA ↔ AoSoA transform invariants (repro/core/layout.py) over
    arbitrary component counts, site counts, and inner widths — including
    remainder blocks (vvl ∤ nsites) and vvl > nsites.  The enumerated
    fallback runs without hypothesis in
    test_layout.py::TestTransforms."""

    @SET
    @given(st.integers(1, 6),             # ncomp
           st.integers(1, 200),           # nsites (odd, prime, tiny...)
           st.integers(1, 64),            # vvl (any, incl. > nsites)
           st.integers(0, 2))             # extra leading batch dims
    def test_roundtrip_exact(self, ncomp, nsites, vvl, nlead):
        from repro.core.layout import (aosoa_nblocks, aosoa_to_soa,
                                       soa_to_aosoa)
        rng = np.random.default_rng(ncomp * 1000 + nsites * 10 + vvl)
        lead = (2,) * nlead
        x = jnp.asarray(rng.normal(size=(*lead, ncomp, nsites)),
                        jnp.float32)
        y = soa_to_aosoa(x, vvl)
        nblk = aosoa_nblocks(nsites, vvl)
        assert y.shape == (nblk, *lead, ncomp, vvl)
        np.testing.assert_array_equal(
            np.asarray(aosoa_to_soa(y, nsites)), np.asarray(x))
        # remainder lanes are zero-padded, never garbage
        pad = nblk * vvl - nsites
        if pad:
            flat = np.moveaxis(np.asarray(y), 0, -2)  # (..., ncomp, nblk, vvl)
            tail = flat.reshape(*lead, ncomp, nblk * vvl)[..., nsites:]
            np.testing.assert_array_equal(tail, 0.0)

    @SET
    @given(st.integers(1, 4),             # ncomp
           st.integers(1, 8),             # nplanes
           st.integers(2, 40),            # plane site count
           st.integers(1, 16))            # vvl candidate
    def test_plane_roundtrip_or_named_error(self, ncomp, npl, rn, vvl):
        """plane_to_aosoa either round-trips exactly (vvl | plane sites)
        or refuses with the no-remainder-blocks error — never silently
        truncates."""
        from repro.core.layout import plane_from_aosoa, plane_to_aosoa
        rng = np.random.default_rng(ncomp + npl * 10 + rn * 100 + vvl)
        x = jnp.asarray(rng.normal(size=(ncomp, npl, rn)), jnp.float32)
        if rn % vvl:
            with pytest.raises(ValueError, match="no remainder blocks"):
                plane_to_aosoa(x, vvl)
            return
        y = plane_to_aosoa(x, vvl)
        assert y.shape == (npl, rn // vvl, ncomp, vvl)
        np.testing.assert_array_equal(
            np.asarray(plane_from_aosoa(y, (rn,))), np.asarray(x))

    @SET
    @given(st.integers(1, 5),             # ncomp
           st.integers(1, 120),           # nsites
           st.sampled_from([1, 2, 4, 8, 16]),
           st.floats(-2, 2))
    def test_gathered_layouts_agree(self, ncomp, nsites, vvl, a):
        """One pointwise launch, every layout×vvl: identical results
        (allclose here; bit-identity is pinned per-executor in
        test_layout.py)."""
        from repro import tdp
        rng = np.random.default_rng(nsites * 10 + ncomp)
        x = jnp.asarray(rng.normal(size=(ncomp, nsites)), jnp.float32)
        spec = tdp.KernelSpec(lambda v, a=1.0: a * v + 1.0,
                              fields=(tdp.FieldSpec(ncomp=ncomp),),
                              out=ncomp, name=f"affine_{ncomp}")
        base = tdp.launch(spec, tdp.Target("xla"), x, a=a)
        for layout in tdp.LAYOUTS:
            t = tdp.Target("xla", vvl=vvl, layout=layout)
            np.testing.assert_array_equal(
                np.asarray(tdp.launch(spec, t, x, a=a)), np.asarray(base))


class TestExchangeProperties:
    """The generalized ghost exchange (repro/core/program.py) against a
    wrap-indexed global reference — any dim, any hop count, widths wider
    than the pencil thickness.  The enumerated fallback (same machinery,
    fixed cases) runs without hypothesis in
    test_program.py::TestPencilExchange."""

    @SET
    @given(st.integers(2, 6),            # nranks
           st.integers(1, 4),            # local extent (1 = thin pencil)
           st.integers(1, 7),            # requested width
           st.integers(1, 3),            # ncomp
           st.integers(0, 1))            # which grid dim is exchanged
    def test_exchange_matches_wrap_indexed_global(self, nranks, loc,
                                                  width, ncomp, dim):
        import importlib
        P = importlib.import_module("repro.core.program")
        glob = nranks * loc
        width = min(width, glob - 1)     # the compile-time width bound
        other = 3                        # extent of the unexchanged dim
        shape = (ncomp, other, glob) if dim == 1 else (ncomp, glob, other)
        rng = np.random.default_rng(nranks * 100 + loc * 10 + width)
        g = rng.normal(size=shape).astype(np.float32)
        ax = dim + 1
        shards = jnp.asarray(np.stack(
            [np.take(g, np.arange(i * loc, (i + 1) * loc), axis=ax)
             for i in range(nranks)]))

        def permute(x, pairs):
            idx = np.zeros(nranks, int)
            for src, dst in pairs:
                idx[dst] = src
            return x[jnp.asarray(idx)]

        # shard dim d is axis d+2 of the stack; exchange_ghosts slices
        # axis dim+1, so shift dim past the rank axis
        got = np.asarray(P.exchange_ghosts(shards, dim + 1, width,
                                           nranks, permute))
        hops = P._exchange_hops(width, loc)
        assert hops[-1][0] == -(-width // loc)
        assert sum(t for _, t in hops) == width
        for i in range(nranks):
            want = np.take(g, np.arange(i * loc - width,
                                        (i + 1) * loc + width) % glob,
                           axis=ax)
            np.testing.assert_array_equal(got[i], want)


class TestAutotuneProperties:
    """Invariants of ``tdp.autotune``'s space construction
    (repro/core/autotune.py)."""

    @staticmethod
    def _star_spec(ndim, radius):
        """A radius-``radius`` axis star stencil spec (1-component)."""
        from repro.core import FieldSpec, KernelSpec, Stencil
        offs = [(0,) * ndim]
        for d in range(ndim):
            for k in range(1, radius + 1):
                for sign in (1, -1):
                    o = [0] * ndim
                    o[d] = sign * k
                    offs.append(tuple(o))
        stc = Stencil(f"star{ndim}d_r{radius}", tuple(offs))
        return KernelSpec(lambda p: p.sum(0, keepdims=True),
                          fields=(FieldSpec(ncomp=1, stencil=stc),),
                          out=(1,), name=f"star_r{radius}")

    @SET
    @given(st.lists(st.integers(4, 24), min_size=1, max_size=3),
           st.integers(1, 2),
           st.sampled_from([0, 2 ** 14, 2 ** 20]))
    def test_plane_block_space_divides_and_fits(self, dims, radius,
                                                vmem_limit):
        """Every emitted plane_block divides the launch's (extended)
        plane count AND passes the vmem_bytes_estimate() filter; every
        divisor is either emitted or pruned with a vmem reason."""
        from repro import tdp
        shape = tuple(dims)
        spec = self._star_spec(len(shape), radius)
        lat = Lattice(shape)
        tgt = tdp.Target("pallas_windowed", interpret=True)
        feasible, pruned = tdp.plane_block_candidates(
            spec, tgt, lat, vmem_limit=vmem_limit)
        nplanes = tdp.launch_plan(spec, tgt, lattice=lat).shape[0]
        assert nplanes == shape[0]
        for p in feasible:
            assert nplanes % p == 0
            plan = tdp.launch_plan(spec, tgt.with_tuning(plane_block=p),
                                   lattice=lat)
            assert plan.vmem_bytes_estimate() <= vmem_limit
        emitted = set(feasible) | {v for v, _ in pruned}
        assert emitted == {d for d in range(1, nplanes + 1)
                           if nplanes % d == 0}
        for v, why in pruned:
            assert "vmem estimate" in why

    @SET
    @given(st.dictionaries(
        st.sampled_from(["plane_block", "block_f", "block_q", "vjp"]),
        st.integers(1, 512), max_size=4),
        st.permutations(["plane_block", "block_f", "block_q", "vjp"]))
    def test_with_tuning_round_trips_freeze_and_hash(self, tuning, order):
        """Equal tuning ⇒ equal Target ⇒ equal hash (the plan-cache-key
        contract), regardless of knob insertion order."""
        from repro import tdp
        base = tdp.Target("pallas_windowed", interpret=True)
        a = base.with_tuning(tuning)
        b = base
        for k in order:                       # knob-at-a-time, any order
            if k in tuning:
                b = b.with_tuning({k: tuning[k]})
        assert a == b
        assert hash(a) == hash(b)
        assert a.tuning_dict() == dict(tuning)
        # merge preserves unrelated knobs; replace-spelling drops them
        c = a.with_tuning(extra=7)
        assert c.tuning_dict() == {**tuning, "extra": 7}
        assert a.with_(tuning={"extra": 7}).tuning_dict() == {"extra": 7}


class TestAttentionProperties:
    @SET
    @given(st.integers(2, 24), st.integers(1, 4), st.booleans())
    def test_causality(self, s, h, use_window):
        """Output at position t never depends on inputs at positions > t."""
        rng = np.random.default_rng(s * h)
        q = jnp.asarray(rng.normal(size=(1, h, s, 8)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, h, s, 8)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, h, s, 8)), jnp.float32)
        window = 4 if use_window else 0
        base = ref.attention_ref(q, k, v, causal=True, window=window)
        t = s // 2
        k2 = k.at[:, :, t + 1:].set(99.0)
        v2 = v.at[:, :, t + 1:].set(-99.0)
        pert = ref.attention_ref(q, k2, v2, causal=True, window=window)
        np.testing.assert_allclose(base[:, :, :t + 1], pert[:, :, :t + 1],
                                   rtol=1e-5, atol=1e-5)

    @SET
    @given(st.integers(8, 64), st.sampled_from([4, 8, 16]))
    def test_chunked_equals_ref_any_blocking(self, s, bq):
        rng = np.random.default_rng(s + bq)
        q = jnp.asarray(rng.normal(size=(1, 2, s, 8)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 2, s, 8)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, 2, s, 8)), jnp.float32)
        a = ref.attention_ref(q, k, v, causal=True)
        b = ref.attention_chunked_ref(q, k, v, causal=True, block_q=bq)
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)

    @SET
    @given(st.floats(1.0, 100.0))
    def test_softcap_bounds_scores(self, cap):
        """Softcapped attention == attention over tanh-bounded scores; the
        output stays a convex combination of V rows."""
        rng = np.random.default_rng(int(cap * 7))
        q = jnp.asarray(10 * rng.normal(size=(1, 1, 8, 4)), jnp.float32)
        k = jnp.asarray(10 * rng.normal(size=(1, 1, 8, 4)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, 1, 8, 4)), jnp.float32)
        out = ref.attention_ref(q, k, v, causal=False, softcap=float(cap))
        vmin, vmax = np.asarray(v).min(), np.asarray(v).max()
        assert (np.asarray(out) >= vmin - 1e-5).all()
        assert (np.asarray(out) <= vmax + 1e-5).all()


class TestQuantProperties:
    @SET
    @given(st.integers(1, 500), st.sampled_from([16, 64, 256]),
           st.floats(1e-3, 1e3))
    def test_error_bound(self, n, block, scale):
        """Global bound: |x - deq(quant(x))| ≤ max|x|/127 elementwise
        (each block's error ≤ its own absmax/127 ≤ the global one)."""
        rng = np.random.default_rng(n + block)
        x = jnp.asarray(rng.normal(size=(n,)) * scale, jnp.float32)
        xr = dequantize_blockwise(quantize_blockwise(x, block), x.shape)
        bound = float(jnp.abs(x).max()) / 127.0 * 1.01 + 1e-9
        assert float(jnp.abs(x - xr).max()) <= bound


class TestLayerProgramProperties:
    @SET
    @given(st.lists(st.sampled_from(["attn", "local", "mamba2"]),
                    min_size=1, max_size=6),
           st.integers(1, 80))
    def test_groups_always_cover(self, pattern, n):
        prog = repeat_program(tuple(pattern), n)
        rebuilt = []
        for unit, k in plan_layer_groups(prog):
            rebuilt.extend(list(unit) * k)
        assert tuple(rebuilt) == prog


class TestMoEProperties:
    @SET
    @given(st.integers(2, 32), st.integers(2, 8), st.integers(1, 4))
    def test_capacity_equals_dense_when_generous(self, t, e, k):
        """cap ≥ T ⇒ dropless ⇒ exactly the dense one-hot computation."""
        if k > e:
            k = e
        from repro.models.moe import _apply_experts_capacity
        from repro.models.config import (ModelConfig, AttnConfig, MoEConfig,
                                         repeat_program)
        from repro.models.context import ExecContext
        cfg = ModelConfig(
            name="p", d_model=8, n_layers=1, vocab_size=32, d_ff=16,
            layer_program=("attn_moe",), attn=AttnConfig(1, 1, 8),
            moe=MoEConfig(num_experts=e, top_k=k, d_expert=8))
        rng = np.random.default_rng(t * e + k)
        xs = jnp.asarray(rng.normal(size=(t, 8)), jnp.float32)
        e_ids = jnp.asarray(rng.integers(0, e, (t,)), jnp.int32)
        p = {"w_up": jnp.asarray(rng.normal(size=(e, 8, 8)), jnp.float32),
             "w_gate": jnp.asarray(rng.normal(size=(e, 8, 8)), jnp.float32),
             "w_down": jnp.asarray(rng.normal(size=(e, 8, 8)), jnp.float32)}
        got = _apply_experts_capacity(xs, e_ids, jnp.ones((t,), bool), p,
                                      cfg, ExecContext(), cap=t)
        # dense reference
        we = np.asarray(p["w_up"])[np.asarray(e_ids)]
        wg = np.asarray(p["w_gate"])[np.asarray(e_ids)]
        wd = np.asarray(p["w_down"])[np.asarray(e_ids)]
        up = np.einsum("td,tdf->tf", np.asarray(xs), we)
        gate = np.einsum("td,tdf->tf", np.asarray(xs), wg)
        act = gate * (1 / (1 + np.exp(-gate))) * up
        want = np.einsum("tf,tfd->td", act, wd)
        np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3,
                                   atol=2e-4)


class TestDataProperties:
    @SET
    @given(st.integers(0, 1000), st.integers(2, 16))
    def test_any_slice_matches_full(self, step, batch):
        from repro.data import SyntheticConfig, batch_for_step
        cfg = SyntheticConfig(vocab_size=50, seq_len=8, global_batch=batch,
                              seed=3)
        full = batch_for_step(cfg, step)
        lo = batch // 3
        hi = max(lo + 1, 2 * batch // 3)
        part = batch_for_step(cfg, step, lo=lo, hi=hi)
        np.testing.assert_array_equal(full["tokens"][lo:hi], part["tokens"])
