"""The first-class AoSoA/VVL layout axis (ISSUE 10).

Pinned here:

* the SoA↔AoSoA transforms are exact inverses for every extent —
  odd sizes, remainder blocks, ``nsites < vvl``, ``ncomp > 1``, and
  leading (``noffsets``) axes — with zero-padded pad lanes;
* every executor (gathered xla / pallas, windowed pallas) produces
  **bit-identical** outputs under ``layout="aosoa"`` for every valid
  vvl, including mixed pointwise+stencil kernels, consts, site_index,
  multi-output, and ``plane_block > 1`` windows;
* the 10-step LB fused trajectory at 16³ is bit-identical across
  layout × vvl × executor;
* the ported LM kernels (rmsnorm / gated_act / mamba_scan) run through
  ``tdp.launch`` on both layouts with bit-identical results — the
  beyond-the-lattice acceptance pin;
* plan-build validation: an indivisible windowed-AoSoA vvl and a
  VMEM-overflowing window each raise *named* compile-time errors;
  ``tdp.autotune`` prunes such candidates instead of crashing;
* the autotune space grows vvl / layout axes, candidate 0 wins ties,
  and cache entries round-trip the new fields.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro import tdp
from repro.core import (
    FieldSpec,
    KernelSpec,
    Lattice,
    Stencil,
    Target,
    WindowVmemError,
    aosoa_to_soa,
    as_target,
    soa_to_aosoa,
)
from repro.core.api import launch, launch_plan
from repro.core.layout import aosoa_nblocks, plane_from_aosoa, plane_to_aosoa


@pytest.fixture
def rng():
    return np.random.default_rng(7)


D3Q7 = Stencil("d3q7", ((0, 0, 0), (1, 0, 0), (-1, 0, 0), (0, 1, 0),
                        (0, -1, 0), (0, 0, 1), (0, 0, -1)))


# ---------------------------------------------------------------------------
# transforms
# ---------------------------------------------------------------------------

class TestTransforms:
    @pytest.mark.parametrize("shape", [(1, 7), (3, 100), (2, 128),
                                       (5, 3, 100), (19, 1, 31)])
    @pytest.mark.parametrize("vvl", [1, 4, 7, 128])
    def test_round_trip_exact(self, rng, shape, vvl):
        """Remainder sites, odd extents, nsites < vvl, leading axes —
        the enumerated fallback for the hypothesis sweep in
        test_properties.py."""
        x = jnp.asarray(rng.normal(size=shape).astype(np.float32))
        y = soa_to_aosoa(x, vvl)
        assert y.shape[0] == aosoa_nblocks(shape[-1], vvl)
        assert y.shape[-1] == vvl
        back = aosoa_to_soa(y, shape[-1])
        np.testing.assert_array_equal(np.asarray(back), np.asarray(x))

    def test_remainder_lanes_zero_padded(self, rng):
        x = jnp.asarray(rng.normal(size=(2, 5)).astype(np.float32))
        y = np.asarray(soa_to_aosoa(x, 4))          # 2 blocks, 3 pad lanes
        assert y.shape == (2, 2, 4)
        np.testing.assert_array_equal(y[1, :, 1:], 0.0)

    def test_aosoa_block_is_contiguous_tile(self, rng):
        """Block b holds components interleaved per block: y[b, c, l] ==
        x[c, b·vvl + l] — the paper's [site-block][component][lane]."""
        x = jnp.asarray(rng.normal(size=(3, 12)).astype(np.float32))
        y = np.asarray(soa_to_aosoa(x, 4))
        xn = np.asarray(x)
        for b in range(3):
            for c in range(3):
                np.testing.assert_array_equal(
                    y[b, c], xn[c, b * 4:(b + 1) * 4])

    def test_plane_round_trip_and_divisibility(self, rng):
        x = jnp.asarray(rng.normal(size=(3, 6, 4, 8)).astype(np.float32))
        y = plane_to_aosoa(x, 8)
        assert y.shape == (6, 4, 3, 8)               # (npl, nblk, ncomp, vvl)
        back = plane_from_aosoa(y, (4, 8))
        np.testing.assert_array_equal(np.asarray(back), np.asarray(x))
        with pytest.raises(ValueError, match="not divisible"):
            plane_to_aosoa(x, 7)

    def test_layout_validated_on_target(self):
        with pytest.raises(ValueError, match="layout"):
            Target("xla", layout="aos")
        assert as_target("xla", layout="aosoa").layout == "aosoa"
        assert Target("xla").layout == "soa"


# ---------------------------------------------------------------------------
# executor bit-identity
# ---------------------------------------------------------------------------

def _mixed_spec():
    def body(f_nb, rho, idx, *, alpha, w):
        # stencil chunk (7, 2, V), pointwise chunk (1, V), site idx (V,)
        acc = (f_nb * w.reshape(-1, 1, 1)).sum(axis=0)     # (2, V)
        return (alpha * acc + rho + (idx % 3).astype(acc.dtype),
                acc[:1] - rho)

    return KernelSpec(
        body, fields=(FieldSpec(2, stencil=D3Q7, name="f"),
                      FieldSpec(1, name="rho")),
        out=(2, 1), site_index=True, consts=("alpha", "w"),
        name="mixed_layout")


class TestExecutorBitIdentity:
    @pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
    @pytest.mark.parametrize("vvl", [32, 60, 128])
    def test_gathered_layouts_identical(self, rng, backend, vvl):
        """Gathered executors: any vvl (remainder pads), mixed stencil +
        pointwise + consts + site_index, multi-output."""
        lat = Lattice((4, 6, 5))
        spec = _mixed_spec()
        f = jnp.asarray(rng.normal(size=(2, lat.nsites)).astype(np.float32))
        r = jnp.asarray(rng.normal(size=(1, lat.nsites)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(7,)).astype(np.float32))
        outs = {}
        for layout in ("soa", "aosoa"):
            t = Target(backend, vvl=vvl, layout=layout)
            outs[layout] = launch(spec, t, f, r, lattice=lat,
                                  consts={"alpha": 1.5, "w": w})
        for a, b in zip(outs["soa"], outs["aosoa"]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("vvl", [8, 16, 32])
    @pytest.mark.parametrize("plane_block", [1, 2, 4])
    def test_windowed_layouts_identical(self, rng, vvl, plane_block):
        """The windowed executor's AoSoA VMEM tiles reproduce the SoA
        path bit-for-bit for every valid vvl × plane_block."""
        lat = Lattice((8, 8, 4))                 # interior plane = 32 sites
        spec = _mixed_spec()
        f = jnp.asarray(rng.normal(size=(2, lat.nsites)).astype(np.float32))
        r = jnp.asarray(rng.normal(size=(1, lat.nsites)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(7,)).astype(np.float32))
        outs = {}
        for layout in ("soa", "aosoa"):
            t = Target("pallas_windowed", vvl=vvl, layout=layout,
                       interpret=True, tuning={"plane_block": plane_block})
            outs[layout] = launch(spec, t, f, r, lattice=lat,
                                  consts={"alpha": 1.5, "w": w})
        for a, b in zip(outs["soa"], outs["aosoa"]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_windowed_matches_xla_under_aosoa(self, rng):
        lat = Lattice((6, 4, 8))
        spec = _mixed_spec()
        f = jnp.asarray(rng.normal(size=(2, lat.nsites)).astype(np.float32))
        r = jnp.asarray(rng.normal(size=(1, lat.nsites)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(7,)).astype(np.float32))
        a = launch(spec, Target("pallas_windowed", vvl=16, layout="aosoa",
                                interpret=True), f, r, lattice=lat,
                   consts={"alpha": 1.5, "w": w})
        b = launch(spec, Target("xla", vvl=64), f, r, lattice=lat,
                   consts={"alpha": 1.5, "w": w})
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.slow
class TestLBTrajectory:
    """Acceptance pin: 10 fused LB steps at 16³, bit-identical across
    layout × vvl × executor."""

    def test_trajectory_layout_sweep(self):
        from repro.lb.params import LBParams
        from repro.lb.sim import BinaryFluidSim

        p = LBParams(A=0.125, B=0.125, kappa=0.02)
        base = BinaryFluidSim((16, 16, 16), params=p, fused="one_launch")
        st0 = base.init_spinodal(seed=3, noise=0.05)
        want = base.step(st0, 10)
        for backend, vvls in [("xla", (64, 128)),
                              ("pallas_windowed", (64, 256))]:
            for vvl in vvls:
                t = Target(backend, vvl=vvl, layout="aosoa",
                           interpret=backend != "xla")
                sim = BinaryFluidSim((16, 16, 16), params=p,
                                     fused="one_launch", target=t)
                got = sim.step(st0, 10)
                np.testing.assert_array_equal(np.asarray(got.f),
                                              np.asarray(want.f))
                np.testing.assert_array_equal(np.asarray(got.g),
                                              np.asarray(want.g))


# ---------------------------------------------------------------------------
# the ported LM kernels (beyond the lattice)
# ---------------------------------------------------------------------------

class TestPortedKernels:
    @pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
    def test_rmsnorm_layouts_identical(self, rng, backend):
        from repro.kernels import ops
        x = jnp.asarray(rng.normal(size=(100, 64)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
        outs = [np.asarray(ops.rmsnorm(
            x, w, target=Target(backend, vvl=32, layout=lay)))
            for lay in ("soa", "aosoa")]
        np.testing.assert_array_equal(outs[0], outs[1])
        from repro.kernels import ref
        np.testing.assert_allclose(outs[0], np.asarray(ref.rmsnorm_ref(x, w)),
                                   rtol=2e-4, atol=2e-5)

    @pytest.mark.parametrize("kind", ["swiglu", "geglu", "relu2"])
    def test_gated_act_layouts_identical(self, rng, kind):
        from repro.kernels import ops
        u = jnp.asarray(rng.normal(size=(33, 48)).astype(np.float32))
        v = (None if kind == "relu2"
             else jnp.asarray(rng.normal(size=(33, 48)).astype(np.float32)))
        outs = [np.asarray(ops.gated_act(
            u, v, kind=kind,
            target=Target("pallas_interpret", vvl=96, layout=lay)))
            for lay in ("soa", "aosoa")]
        np.testing.assert_array_equal(outs[0], outs[1])

    def test_mamba_scan_layouts_identical(self, rng):
        from repro.kernels import ops
        batch, L, d_inner, n = 2, 24, 48, 8
        x = jnp.asarray(rng.normal(size=(batch, L, d_inner)), jnp.float32)
        dt = jnp.asarray(0.1 * abs(rng.normal(size=(batch, L, d_inner))),
                         jnp.float32)
        b = jnp.asarray(rng.normal(size=(batch, L, n)), jnp.float32)
        c = jnp.asarray(rng.normal(size=(batch, L, n)), jnp.float32)
        a = jnp.asarray(-abs(rng.normal(size=(d_inner, n))), jnp.float32)
        d = jnp.asarray(rng.normal(size=(d_inner,)), jnp.float32)
        got = {}
        for lay in ("soa", "aosoa"):
            t = Target("pallas_interpret", vvl=16, layout=lay)
            got[lay] = ops.mamba_scan(x, dt, b, c, a, d, target=t)
        for u, v in zip(got["soa"], got["aosoa"]):
            np.testing.assert_array_equal(np.asarray(u), np.asarray(v))
        from repro.kernels import ref
        y_ref, h_ref = ref.mamba_scan_ref(x, dt, b, c, a, d)
        np.testing.assert_allclose(np.asarray(got["soa"][0]),
                                   np.asarray(y_ref), rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(got["soa"][1]),
                                   np.asarray(h_ref), rtol=2e-4, atol=2e-4)

    def test_rmsnorm_weight_gradient_flows(self, rng):
        """The weight rides as a dynamic const — jax.grad must see it."""
        import jax
        from repro.kernels import ops
        x = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(8,)).astype(np.float32))

        def loss(w_, backend):
            return (ops.rmsnorm(x, w_, target=Target(backend)) ** 2).sum()

        g_xla = jax.grad(lambda w_: loss(w_, "xla"))(w)
        assert float(jnp.abs(g_xla).max()) > 0


# ---------------------------------------------------------------------------
# plan-build validation (satellites 2 + 3)
# ---------------------------------------------------------------------------

class TestValidation:
    def test_windowed_aosoa_indivisible_vvl_named_error(self, rng):
        lat = Lattice((8, 8, 8))
        spec = _mixed_spec()
        f = jnp.zeros((2, lat.nsites), jnp.float32)
        r = jnp.zeros((1, lat.nsites), jnp.float32)
        t = Target("pallas_windowed", vvl=7, layout="aosoa", interpret=True)
        with pytest.raises(ValueError) as ei:
            launch(spec, t, f, r, lattice=lat,
                   consts={"alpha": 1.0, "w": jnp.ones((7,))})
        msg = str(ei.value)
        assert "mixed_layout" in msg and "vvl=7" in msg and "64" in msg

    def test_gathered_aosoa_any_vvl_valid(self, rng):
        """Remainder sites pad on gathered executors — vvl=7 is fine."""
        lat = Lattice((8, 8, 8))
        spec = _mixed_spec()
        f = jnp.asarray(rng.normal(size=(2, lat.nsites)), jnp.float32)
        r = jnp.asarray(rng.normal(size=(1, lat.nsites)), jnp.float32)
        t = Target("pallas", vvl=7, layout="aosoa", interpret=True)
        out = launch(spec, t, f, r, lattice=lat,
                     consts={"alpha": 1.0, "w": jnp.ones((7,))})
        assert out[0].shape == (2, lat.nsites)

    def test_window_vmem_overflow_named_error(self):
        """Satellite 2: a plane_block window that exceeds the VMEM cap
        fails at plan build, naming the worst field and the byte count —
        not deep inside Mosaic."""
        lat = Lattice((4, 512, 512))
        spec = _mixed_spec()
        f = jnp.zeros((2, lat.nsites), jnp.float32)
        r = jnp.zeros((1, lat.nsites), jnp.float32)
        t = Target("pallas_windowed", interpret=True,
                   tuning={"plane_block": 4})
        with pytest.raises(WindowVmemError) as ei:
            launch(spec, t, f, r, lattice=lat,
                   consts={"alpha": 1.0, "w": jnp.ones((7,))})
        msg = str(ei.value)
        assert "mixed_layout" in msg and "plane_block=4" in msg
        assert "f" in msg and "VMEM" not in msg.split()[:1]  # named error

    def test_launch_plan_skips_vmem_guard(self):
        """launch_plan must stay buildable over the cap so autotune can
        estimate-and-prune instead of crashing."""
        lat = Lattice((4, 512, 512))
        spec = _mixed_spec()
        t = Target("pallas_windowed", interpret=True,
                   tuning={"plane_block": 4})
        plan = launch_plan(spec, t, lattice=lat)
        assert plan.vmem_bytes_estimate() > 16 * 2 ** 20

    def test_aosoa_hbm_estimate_doubles(self):
        lat = Lattice((8, 8, 8))
        spec = _mixed_spec()
        soa = launch_plan(spec, Target("pallas_windowed", vvl=8,
                                       interpret=True), lattice=lat)
        aos = launch_plan(spec, Target("pallas_windowed", vvl=8,
                                       layout="aosoa", interpret=True),
                          lattice=lat)
        assert aos.hbm_bytes_estimate() == 2 * soa.hbm_bytes_estimate()


# ---------------------------------------------------------------------------
# autotune integration (satellite 1)
# ---------------------------------------------------------------------------

class TestAutotuneLayoutAxis:
    def test_default_space_grows_vvl_and_layout_axes(self):
        from repro.core.autotune import default_space

        def body(a):
            return 2.0 * a
        spec = KernelSpec(body, fields=(FieldSpec(3),), out=(3,), name="s")
        cands, _ = default_space(spec, Target("pallas", interpret=True),
                                 site_count=1024)
        labels = [c.label for c in cands]
        assert any("vvl=" in l and "layout" not in l for l in labels)
        assert any("layout=aosoa" in l for l in labels)

    def test_windowed_space_layout_vvls_divide_plane(self):
        from repro.core.autotune import default_space
        lat = Lattice((8, 8, 8))
        spec = _mixed_spec()
        cands, _ = default_space(
            spec, Target("pallas_windowed", interpret=True), lattice=lat)
        aosoa = [c for c in cands if c.layout == "aosoa"
                 and c.backend == "pallas_windowed"]
        assert aosoa, "windowed space must carry aosoa candidates"
        assert all(64 % c.vvl == 0 for c in aosoa)

    def test_candidate_zero_wins_ties(self, rng, tmp_path):
        """A constant-time fake timer makes every candidate tie — the
        tuner must keep the base target, not an exotic layout."""
        from repro.core.autotune import autotune
        lat = Lattice((8, 8, 8))
        spec = _mixed_spec()
        f = jnp.asarray(rng.normal(size=(2, lat.nsites)), jnp.float32)
        r = jnp.asarray(rng.normal(size=(1, lat.nsites)), jnp.float32)
        tgt, report = autotune(
            spec, Target("xla", vvl=64), [f, r], lattice=lat,
            consts={"alpha": 1.0, "w": jnp.ones((7,))},
            timer=lambda t, run: 1.0, reps=1, warmup=0,
            cache_dir=str(tmp_path))
        assert report.best == report.results[0].candidate
        assert tgt.executor == "xla" and tgt.layout == "soa"

    def test_candidate_round_trips_layout_fields(self):
        from repro.core.autotune import Candidate
        c = Candidate("pallas", True, (("plane_block", 2),), 64, "aosoa")
        c2 = Candidate.from_dict(c.as_dict())
        assert c2 == c and c2.vvl == 64 and c2.layout == "aosoa"
        legacy = Candidate.from_dict({"backend": "xla"})   # v1/v2 entry
        assert legacy.vvl is None and legacy.layout is None
        assert "layout=aosoa" in c.label and "vvl=64" in c.label

    def test_vvl_invalid_candidate_pruned_not_fatal(self, rng, tmp_path):
        """An explicit-space candidate whose windowed-AoSoA vvl doesn't
        divide the plane count is pruned during measurement (the
        satellite-2/3 contract: named errors, autotune survives)."""
        from repro.core.autotune import Candidate, autotune
        lat = Lattice((8, 8, 8))
        spec = _mixed_spec()
        f = jnp.asarray(rng.normal(size=(2, lat.nsites)), jnp.float32)
        r = jnp.asarray(rng.normal(size=(1, lat.nsites)), jnp.float32)
        bad = Candidate("pallas_windowed", True, vvl=7, layout="aosoa")
        good = Candidate("pallas_windowed", True, vvl=16, layout="aosoa")
        tgt, report = autotune(
            spec, Target("xla", vvl=64), [f, r], lattice=lat,
            consts={"alpha": 1.0, "w": jnp.ones((7,))},
            space=[bad, good], timer=lambda t, run: 1.0, reps=1, warmup=0,
            check_identical=True, cache_dir=str(tmp_path))
        assert any(bad.label == l for l, _ in report.pruned)
        assert {r_.candidate.label for r_ in report.results} >= \
            {good.label}
