"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracle.

Every kernel × a shape/dtype grid, per the brief.  interpret=True executes
the Pallas body on CPU with real BlockSpec tiling semantics, so these pin
the single-source equivalence the paper's portability claim rests on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.lb.params import LBParams


def _rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-5)


class TestLBCollision:
    @pytest.mark.parametrize("nsites", [64, 200, 1024])
    @pytest.mark.parametrize("vvl", [64, 128])
    def test_allclose(self, nsites, vvl):
        p = LBParams()
        f = 0.05 * _rand(0, (19, nsites), jnp.float32) + 1.0 / 19
        g = 0.05 * _rand(1, (19, nsites), jnp.float32)
        phi = g.sum(0, keepdims=True)
        gp = 0.01 * _rand(2, (3, nsites), jnp.float32)
        d2 = 0.01 * _rand(3, (1, nsites), jnp.float32)
        fo_i, go_i = ops.lb_collision(f, g, phi, gp, d2, vvl=vvl,
                                      backend="pallas_interpret",
                                      **p.as_kwargs())
        fo_r, go_r = ops.lb_collision(f, g, phi, gp, d2, **p.as_kwargs())
        np.testing.assert_allclose(fo_i, fo_r, rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(go_i, go_r, rtol=2e-5, atol=2e-5)

    def test_conservation(self):
        """Collision conserves mass (Σf) and order parameter (Σg) per site."""
        p = LBParams()
        n = 256
        f = 0.05 * _rand(0, (19, n), jnp.float32) + 1.0 / 19
        g = 0.05 * _rand(1, (19, n), jnp.float32)
        phi = g.sum(0, keepdims=True)
        gp = jnp.zeros((3, n))
        d2 = jnp.zeros((1, n))
        fo, go = ops.lb_collision(f, g, phi, gp, d2, **p.as_kwargs())
        np.testing.assert_allclose(fo.sum(0), f.sum(0), rtol=1e-5)
        np.testing.assert_allclose(go.sum(0), g.sum(0), rtol=1e-5, atol=1e-6)


class TestRMSNorm:
    @pytest.mark.parametrize("t,d", [(64, 128), (100, 256), (1, 512)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("vvl", [32, 256])
    def test_allclose(self, t, d, dtype, vvl):
        x = _rand(0, (t, d), dtype)
        w = _rand(1, (d,), jnp.float32)
        got = ops.rmsnorm(x, w, backend="pallas_interpret", vvl=vvl)
        want = ref.rmsnorm_ref(x, w)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), **_tol(dtype))

    def test_scale_offset(self):
        x = _rand(0, (32, 64), jnp.float32)
        w = jnp.zeros((64,))
        got = ops.rmsnorm(x, w, backend="pallas_interpret", scale_offset=1.0)
        want = ref.rmsnorm_ref(x, w, scale_offset=1.0)
        np.testing.assert_allclose(got, want, rtol=2e-5)


class TestGatedAct:
    @pytest.mark.parametrize("kind", ["swiglu", "geglu", "relu2"])
    @pytest.mark.parametrize("t,f", [(64, 256), (33, 512)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_allclose(self, kind, t, f, dtype):
        u = _rand(0, (t, f), dtype)
        v = None if kind == "relu2" else _rand(1, (t, f), dtype)
        got = ops.gated_act(u, v, kind=kind, backend="pallas_interpret")
        want = ref.gated_act_ref(u, v, kind=kind)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), **_tol(dtype))


class TestFlashAttention:
    @pytest.mark.parametrize("sq,sk,hq,hkv,dh", [
        (128, 128, 4, 4, 32),
        (128, 128, 8, 2, 64),     # GQA
        (256, 256, 4, 1, 32),     # MQA
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_allclose(self, sq, sk, hq, hkv, dh, causal):
        q = _rand(0, (2, hq, sq, dh), jnp.float32)
        k = _rand(1, (2, hkv, sk, dh), jnp.float32)
        v = _rand(2, (2, hkv, sk, dh), jnp.float32)
        got = ops.flash_attention(q, k, v, causal=causal,
                                  backend="pallas_interpret",
                                  block_q=64, block_k=64)
        want = ref.attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("window", [16, 64])
    def test_sliding_window(self, window):
        q = _rand(0, (1, 2, 128, 32), jnp.float32)
        k = _rand(1, (1, 2, 128, 32), jnp.float32)
        v = _rand(2, (1, 2, 128, 32), jnp.float32)
        got = ops.flash_attention(q, k, v, causal=True, window=window,
                                  backend="pallas_interpret",
                                  block_q=32, block_k=32)
        want = ref.attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_softcap(self):
        q = _rand(0, (1, 2, 64, 32), jnp.float32)
        k = _rand(1, (1, 2, 64, 32), jnp.float32)
        v = _rand(2, (1, 2, 64, 32), jnp.float32)
        got = ops.flash_attention(q, k, v, causal=True, softcap=30.0,
                                  backend="pallas_interpret",
                                  block_q=32, block_k=32)
        want = ref.attention_ref(q, k, v, causal=True, softcap=30.0)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("bq", [16, 64, 512])
    def test_chunked_oracle_equals_ref(self, bq):
        """The dry-run's memory-bounded path is bit-for-bit the oracle."""
        q = _rand(3, (2, 4, 96, 32), jnp.float32)
        k = _rand(4, (2, 2, 96, 32), jnp.float32)
        v = _rand(5, (2, 2, 96, 32), jnp.float32)
        got = ref.attention_chunked_ref(q, k, v, causal=True, block_q=bq)
        want = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


class TestMambaScan:
    @pytest.mark.parametrize("b,t,d,n", [(1, 64, 32, 8), (2, 128, 64, 16)])
    @pytest.mark.parametrize("block_t", [32, 64])
    def test_allclose(self, b, t, d, n, block_t):
        x = _rand(0, (b, t, d), jnp.float32)
        dt = jax.nn.softplus(_rand(1, (b, t, d), jnp.float32))
        bb = _rand(2, (b, t, n), jnp.float32)
        cc = _rand(3, (b, t, n), jnp.float32)
        a = -jnp.exp(_rand(4, (d, n), jnp.float32))
        dd = jnp.ones((d,))
        y_i, h_i = ops.mamba_scan(x, dt, bb, cc, a, dd,
                                  backend="pallas_interpret",
                                  block_t=block_t)
        y_r, h_r = ref.mamba_scan_ref(x, dt, bb, cc, a, dd)
        np.testing.assert_allclose(y_i, y_r, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(h_i, h_r, rtol=2e-4, atol=2e-4)


class TestTdpPointwise:
    """The generic Pallas site-kernel executor (TARGET_ILP tiling)."""

    @pytest.mark.parametrize("ncomp,nsites,vvl", [
        (1, 128, 32), (19, 96, 32), (3, 1000, 128)])
    def test_generic_kernel(self, ncomp, nsites, vvl, rng):
        from repro import core as tdp

        @tdp.site_kernel
        def poly(x, a=1.0):
            return a * x * x - x

        x = jnp.asarray(rng.normal(size=(ncomp, nsites)), jnp.float32)
        got = tdp.launch(poly, None, [x], consts={"a": 0.7}, vvl=vvl,
                         backend="pallas_interpret")
        want = tdp.launch(poly, None, [x], consts={"a": 0.7}, vvl=vvl,
                          backend="xla")
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
