"""tdp.fleet: ensemble execution, the service driver, durability.

Covers the three layers:

* ``ProgramState`` — annotated pytree (names + optional ensemble axis),
  Mapping protocol, stack/member/unstack, validation messages that name
  the offending field and dimension.
* ``FleetProgram`` — ``compiled.vmap(batch)``: fleet trajectories are
  **bit-identical** to per-member runs.  The exact reference depends on
  the const story: programs with only static consts compare against
  plain single ``CompiledProgram`` runs; ``BatchedConst`` sweeps compare
  against batch-1 fleets (XLA constant-folds a *baked* scalar — e.g.
  ``/tau`` → multiply-by-reciprocal — so a static-const solo compile is
  the same trajectory only to ~1 ulp, while the served path is exact).
* ``FleetDriver`` — submit/poll/stream/drain, bucket reuse (one jit per
  sweep), warn-once per-member fallback for unbucketed grids, and
  kill-and-restore through the checkpoint store matching an
  uninterrupted run bit-for-bit.

The sharded case (vmap outside ``shard_map``) runs in a subprocess with
fake devices under the ``slow`` marker, like tests/test_distributed.py.
"""
import os
import subprocess
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import tdp
from repro.lb import programs as lbp
from repro.lb.params import LBParams
from repro.lb.sim import BinaryFluidSim


# ---------------------------------------------------------------------------
# fixtures: a tiny 2-stage program with a sweepable const
# ---------------------------------------------------------------------------

@tdp.kernel(fields=[tdp.field(2)], out=2)
def _relax(x, tau=1.0, w=None):
    return x - (x - w[:, None]) / tau


@tdp.kernel(fields=[tdp.field(2), tdp.field(2)], out=2)
def _mix(x, y, eps=0.1):
    return x + eps * (y - x)


GRID = (6, 5)
W = tdp.TargetConst(np.array([0.25, 0.75], np.float32))


def make_prog(tau_const, name="demo"):
    return tdp.Program(name, [
        tdp.stage(_relax, ["a"], ["tmp"],
                  consts={"tau": tau_const, "w": W}),
        tdp.stage(_mix, ["a", "tmp"], ["a"], consts={"eps": 0.05}),
    ], fields=["a"])


def members(n, seed=0, grid=GRID):
    rng = np.random.default_rng(seed)
    return [{"a": jnp.asarray(
        rng.normal(size=(2,) + grid).astype(np.float32))}
        for _ in range(n)]


# ---------------------------------------------------------------------------
# ProgramState
# ---------------------------------------------------------------------------

class TestProgramState:
    def test_mapping_protocol(self):
        m = members(1)[0]
        s = tdp.ProgramState(m)
        assert list(s) == ["a"] and len(s) == 1 and s.fields == ("a",)
        assert s["a"] is m["a"] and dict(s)["a"] is m["a"]
        assert s.ensemble is None
        with pytest.raises(KeyError, match="no field 'b'.*fields: \\['a'\\]"):
            s["b"]

    def test_pytree_roundtrip_preserves_annotation(self):
        s = tdp.ProgramState.stack(members(3))
        leaves, treedef = jax.tree_util.tree_flatten(s)
        s2 = jax.tree_util.tree_unflatten(treedef, leaves)
        assert isinstance(s2, tdp.ProgramState)
        assert s2.ensemble == 3 and s2.fields == ("a",)
        # survives a jitted identity (annotation lives in aux data)
        s3 = jax.jit(lambda x: x)(s)
        assert isinstance(s3, tdp.ProgramState) and s3.ensemble == 3

    def test_stack_member_unstack(self):
        ms = members(4)
        s = tdp.ProgramState.stack(ms)
        assert s.ensemble == 4 and s["a"].shape == (4, 2) + GRID
        for i, m in enumerate(ms):
            np.testing.assert_array_equal(np.asarray(s.member(i)["a"]),
                                          np.asarray(m["a"]))
        parts = s.unstack()
        assert len(parts) == 4 and all(p.ensemble is None for p in parts)
        with pytest.raises(ValueError, match="already carries an ensemble"):
            tdp.ProgramState.stack([s, s])

    def test_replace(self):
        s = tdp.ProgramState(members(1)[0])
        z = jnp.zeros((2,) + GRID, np.float32)
        s2 = s.replace(a=z)
        assert s2["a"] is z and s["a"] is not z
        with pytest.raises(ValueError, match="unknown field"):
            s.replace(b=z)

    def test_validation_names_field_and_dim(self):
        bad_ncomp = {"a": jnp.zeros((3,) + GRID, np.float32)}
        with pytest.raises(ValueError,
                           match="field 'a'.*dim 0 \\(ncomp\\) is 3.*"
                                 "expected ncomp 2"):
            tdp.ProgramState(bad_ncomp).validate({"a": 2}, GRID)
        bad_grid = {"a": jnp.zeros((2, 6, 7), np.float32)}
        with pytest.raises(ValueError,
                           match="dim 2 \\(grid dim 1\\) is 7.*"
                                 "expected grid extent 5"):
            tdp.ProgramState(bad_grid).validate({"a": 2}, GRID)
        ens = tdp.ProgramState.stack(members(3))
        with pytest.raises(ValueError,
                           match="dim 0 \\(ensemble\\) is 3.*"
                                 "expected ensemble extent 4"):
            tdp.validate_field("a", ens["a"], ncomp=2, grid_shape=GRID,
                               ensemble=4)

    def test_compiled_program_accepts_program_state(self):
        cp = make_prog(tdp.TargetConst(np.float32(0.9))).compile(
            "xla", grid_shape=GRID)
        m = members(1)[0]
        out_dict = cp.run(dict(m), 3)
        out_ps = cp.run(tdp.ProgramState(m), 3)
        assert isinstance(out_dict, dict)
        assert isinstance(out_ps, tdp.ProgramState)
        np.testing.assert_array_equal(np.asarray(out_dict["a"]),
                                      np.asarray(out_ps["a"]))
        # ensembled state is rejected with a pointer to fleets
        with pytest.raises(ValueError, match="fleet|member"):
            cp.step(tdp.ProgramState.stack(members(2)))


# ---------------------------------------------------------------------------
# BatchedConst
# ---------------------------------------------------------------------------

class TestBatchedConst:
    def test_needs_leading_axis(self):
        with pytest.raises(ValueError, match="leading ensemble axis"):
            tdp.BatchedConst(3.0)
        bc = tdp.BatchedConst(np.arange(4.0))
        assert bc.batch == 4 and bc.member_shape() == ()

    def test_bare_launch_rejected(self):
        prog = make_prog(tdp.BatchedConst(np.ones(4, np.float32)))
        cp = prog.compile("xla", grid_shape=GRID)
        with pytest.raises(ValueError, match="vmap\\(batch\\)"):
            cp.run(members(1)[0], 1)
        with pytest.raises(ValueError, match="fleet"):
            tdp.launch(_relax, "xla",
                       members(1)[0]["a"].reshape(2, -1),
                       tau=tdp.BatchedConst(np.ones(4, np.float32)), w=W)

    def test_conflicting_sweeps_rejected(self):
        b1 = tdp.BatchedConst(np.arange(4.0))
        b2 = tdp.BatchedConst(np.arange(4.0) + 1)
        prog = tdp.Program("x", [
            tdp.stage(_relax, ["a"], ["tmp"], consts={"tau": b1, "w": W}),
            tdp.stage(_relax, ["tmp"], ["a"], consts={"tau": b2, "w": W}),
        ], fields=["a"])
        with pytest.raises(ValueError, match="two different BatchedConst"):
            prog.batched_consts()

    def test_batch_mismatch_names_const(self):
        prog = make_prog(tdp.BatchedConst(np.ones(4, np.float32)))
        cp = prog.compile("xla", grid_shape=GRID)
        with pytest.raises(ValueError, match="'tau' sweeps 4.*batch is 3"):
            cp.vmap(3)


# ---------------------------------------------------------------------------
# FleetProgram bit-identity
# ---------------------------------------------------------------------------

EXECUTORS = [
    tdp.Target("xla", vvl=32),
    tdp.Target("pallas", vvl=32, interpret=True),
]


class TestFleetBitIdentity:
    @pytest.mark.parametrize("target", EXECUTORS,
                             ids=["xla", "pallas_interpret"])
    @pytest.mark.parametrize("batch", [1, 4])
    def test_static_consts_match_single_runs(self, target, batch):
        prog = make_prog(tdp.TargetConst(np.float32(0.9)))
        cp = prog.compile(target, grid_shape=GRID)
        fleet = cp.vmap(batch)
        ms = members(batch)
        out = fleet.run(tdp.ProgramState.stack(ms), 5)
        assert isinstance(out, tdp.ProgramState) and out.ensemble == batch
        for i in range(batch):
            ref = cp.run(dict(ms[i]), 5)
            np.testing.assert_array_equal(np.asarray(out["a"][i]),
                                          np.asarray(ref["a"]))

    @pytest.mark.parametrize("target", EXECUTORS,
                             ids=["xla", "pallas_interpret"])
    def test_sweep_matches_batch1_fleets(self, target):
        B = 4
        taus = np.linspace(0.6, 1.4, B).astype(np.float32)
        fleet = make_prog(tdp.BatchedConst(taus)).compile(
            target, grid_shape=GRID).vmap(B)
        ms = members(B)
        out = fleet.run(tdp.ProgramState.stack(ms), 6)
        for i in range(B):
            f1 = make_prog(tdp.BatchedConst(taus[i:i + 1])).compile(
                target, grid_shape=GRID).vmap(1)
            ref = f1.run({"a": ms[i]["a"][None]}, 6)
            np.testing.assert_array_equal(np.asarray(out["a"][i]),
                                          np.asarray(ref["a"][0]))

    def test_step_equals_run_chunks(self):
        prog = make_prog(tdp.TargetConst(np.float32(0.8)))
        fleet = prog.compile("xla", grid_shape=GRID).vmap(2)
        s = tdp.ProgramState.stack(members(2))
        a = fleet.run(s, 4)
        b = s
        for _ in range(4):
            b = fleet.step(b)
        np.testing.assert_array_equal(np.asarray(a["a"]),
                                      np.asarray(b["a"]))

    def test_const_override_no_recompile(self):
        B = 3
        fleet = make_prog(tdp.BatchedConst(
            np.ones(B, np.float32))).compile(
            "xla", grid_shape=GRID).vmap(B)
        s = tdp.ProgramState.stack(members(B))
        fleet.run(s, 2)
        n_compiled = len(fleet._run_cache)
        fleet.run(s, 2, consts={"tau": np.full(B, 0.7, np.float32)})
        assert len(fleet._run_cache) == n_compiled   # same jit entry
        with pytest.raises(ValueError, match="binds no batched const"):
            fleet.run(s, 1, consts={"nope": np.ones(B)})
        with pytest.raises(ValueError, match="'tau'.*expected the fleet"):
            fleet.run(s, 1, consts={"tau": np.ones(B + 1, np.float32)})

    def test_state_validation_messages(self):
        fleet = make_prog(tdp.TargetConst(np.float32(0.9))).compile(
            "xla", grid_shape=GRID).vmap(2)
        with pytest.raises(ValueError, match="must carry an ensemble axis"):
            fleet.step(tdp.ProgramState(members(1)[0]))
        with pytest.raises(ValueError, match="ensemble extent 3 != fleet"):
            fleet.step(tdp.ProgramState.stack(members(3)))
        with pytest.raises(ValueError,
                           match="field 'a'.*dim 0 \\(ensemble\\)"):
            fleet.step({"a": jnp.zeros((3, 2) + GRID, np.float32)})

    def test_lb_fleet_matches_single_sims(self):
        """The acceptance case: a fleet of BinaryFluidSim trajectories
        is bit-identical to independent single runs."""
        sim = BinaryFluidSim(grid_shape=(8, 8, 8), backend="xla", vvl=64,
                             fused="two_launch")
        fused = sim.programs["fused"]
        B = 3
        states = []
        for seed in range(B):
            st = sim.init_spinodal(seed=seed)
            st = sim.programs["collide"].run({"f": st.f, "g": st.g}, 1)
            states.append(st)
        fleet = fused.vmap(B)
        out = fleet.run(tdp.ProgramState.stack(states), 4)
        for i in range(B):
            ref = fused.run(dict(states[i]), 4)
            for f in ("f", "g"):
                np.testing.assert_array_equal(np.asarray(out[f][i]),
                                              np.asarray(ref[f]))

    def test_lb_mobility_sweep(self):
        """Per-member tau_phi (mobility) sweep through BatchedConst."""
        B = 3
        tau_phis = np.array([0.8, 1.0, 1.2], np.float32)
        p = LBParams()

        def build(tau_phi_const):
            phys = p.as_kwargs()
            phys["tau_phi"] = tau_phi_const
            return lbp.unfused_step_program(
                lbp.collision_consts(np.float32, **phys))

        sim = BinaryFluidSim(grid_shape=(8, 8, 8), backend="xla", params=p)
        states = [sim.init_spinodal(seed=s) for s in range(B)]
        ms = [{"f": s.f, "g": s.g} for s in states]
        fleet = build(tdp.BatchedConst(tau_phis)).compile(
            "xla", grid_shape=(8, 8, 8)).vmap(B)
        out = fleet.run(tdp.ProgramState.stack(ms), 3)
        for i in range(B):
            f1 = build(tdp.BatchedConst(tau_phis[i:i + 1])).compile(
                "xla", grid_shape=(8, 8, 8)).vmap(1)
            ref = f1.run({k: v[None] for k, v in ms[i].items()}, 3)
            for f in ("f", "g"):
                np.testing.assert_array_equal(np.asarray(out[f][i]),
                                              np.asarray(ref[f][0]))


class TestFleetWindowed:
    def test_windowed_fleet_matches_windowed_singles(self):
        """Fleet bit-identity under the windowed (halo-extended)
        executor: fleet members == single runs of the same compile."""
        sim = BinaryFluidSim(grid_shape=(8, 8, 8), backend="xla",
                             fused="one_launch")
        st = sim.init_spinodal(seed=0)
        m0 = sim.programs["collide"].run({"f": st.f, "g": st.g}, 1)
        st1 = sim.init_spinodal(seed=1)
        m1 = sim.programs["collide"].run({"f": st1.f, "g": st1.g}, 1)
        consts = lbp.collision_consts(np.float32,
                                      **LBParams().as_kwargs())
        fusedp = lbp.fused_program("one_launch", consts)
        cp = fusedp.compile(tdp.Target("pallas_windowed", interpret=True),
                            grid_shape=(8, 8, 8))
        fleet = cp.vmap(2)
        out = fleet.run(tdp.ProgramState.stack([m0, m1]), 2)
        for i, m in enumerate([m0, m1]):
            ref = cp.run(dict(m), 2)
            for f in ("f", "g"):
                np.testing.assert_array_equal(np.asarray(out[f][i]),
                                              np.asarray(ref[f]))


# ---------------------------------------------------------------------------
# FleetDriver
# ---------------------------------------------------------------------------

class TestFleetDriver:
    def test_submit_poll_stream_drain_static(self):
        prog = make_prog(tdp.TargetConst(np.float32(0.9)))
        cp = prog.compile("xla", grid_shape=GRID)
        drv = tdp.FleetDriver("xla", batch=3)
        ms = members(4)
        ts = [drv.submit(prog, {"state": ms[i]}, 5 + i) for i in range(4)]
        marks = [s for s, _ in drv.stream(ts[0], every=2)]
        assert marks == [2, 4, 5]
        final = drv.drain()
        for i, t in enumerate(ts):
            ref = cp.run(dict(ms[i]), 5 + i)
            np.testing.assert_array_equal(np.asarray(final[t.id]["a"]),
                                          np.asarray(ref["a"]))
            p = drv.poll(t)
            assert p["done"] and p["step"] == 5 + i
        # 4 tickets > 3 slots still used exactly one bucket (one jit)
        assert len(drv._buckets) == 1

    def test_sweep_bucket_one_jit(self):
        prog = make_prog(tdp.TargetConst(np.float32(1.0)))
        B = 3
        taus = np.array([0.7, 1.0, 1.3], np.float32)
        drv = tdp.FleetDriver("xla", batch=B)
        ms = members(B)
        ts = [drv.submit(prog, {"state": ms[i], "consts": {"tau": taus[i]}},
                         6) for i in range(B)]
        final = drv.drain()
        assert len(drv._buckets) == 1
        for i, t in enumerate(ts):
            f1 = make_prog(tdp.BatchedConst(taus[i:i + 1])).compile(
                "xla", grid_shape=GRID).vmap(1)
            ref = f1.run({"a": ms[i]["a"][None]}, 6)
            np.testing.assert_array_equal(np.asarray(final[t.id]["a"]),
                                          np.asarray(ref["a"][0]))

    def test_fallback_warns_once_and_completes(self):
        prog = make_prog(tdp.TargetConst(np.float32(0.9)))
        drv = tdp.FleetDriver("xla", batch=2, grid_shapes=[GRID])
        odd = (4, 4)
        with warnings.catch_warnings(record=True) as wlist:
            warnings.simplefilter("always")
            t1 = drv.submit(prog, {"state": {
                "a": jnp.ones((2,) + odd, np.float32)}}, 3)
            t2 = drv.submit(prog, {"state": {
                "a": jnp.zeros((2,) + odd, np.float32)}}, 3)
        msgs = [x for x in wlist if "per-member" in str(x.message)]
        assert len(msgs) == 1 and "(4, 4)" in str(msgs[0].message)
        final = drv.drain()
        cp = prog.compile("xla", grid_shape=odd)
        ref = cp.run({"a": jnp.ones((2,) + odd, np.float32)}, 3)
        np.testing.assert_array_equal(np.asarray(final[t1.id]["a"]),
                                      np.asarray(ref["a"]))
        assert t1.bucket_id == "" and t2.done
        # bucketed grid still goes through the fleet path
        t3 = drv.submit(prog, {"state": members(1)[0]}, 2)
        drv.drain()
        assert t3.bucket_id != ""

    def test_background_thread(self):
        prog = make_prog(tdp.TargetConst(np.float32(0.9)))
        cp = prog.compile("xla", grid_shape=GRID)
        drv = tdp.FleetDriver("xla", batch=2)
        drv.start()
        try:
            m = members(1)[0]
            t = drv.submit(prog, {"state": m}, 12)
            final = drv.drain()
        finally:
            drv.stop()
        ref = cp.run(dict(m), 12)
        np.testing.assert_array_equal(np.asarray(final[t.id]["a"]),
                                      np.asarray(ref["a"]))

    def test_submit_validation(self):
        prog = make_prog(tdp.TargetConst(np.float32(0.9)))
        drv = tdp.FleetDriver("xla", batch=2)
        with pytest.raises(ValueError, match="one member per ticket"):
            drv.submit(prog, {"state": tdp.ProgramState.stack(members(2))},
                       3)
        with pytest.raises(ValueError, match="nsteps"):
            drv.submit(prog, {"state": members(1)[0]}, 0)
        with pytest.raises(ValueError, match="no stage binds const"):
            drv.submit(prog, {"state": members(1)[0],
                              "consts": {"zeta": 1.0}}, 3)


class TestFleetDurability:
    def test_kill_and_resume_matches_uninterrupted(self, tmp_path):
        prog = make_prog(tdp.TargetConst(np.float32(1.0)))
        taus = np.array([0.7, 1.1], np.float32)
        ms = members(2)
        ck = str(tmp_path / "ck")

        drv = tdp.FleetDriver("xla", batch=2, checkpoint_dir=ck)
        tA = drv.submit(prog, {"state": ms[0], "consts": {"tau": taus[0]},
                               "rng": jax.random.PRNGKey(3)}, 9)
        tB = drv.submit(prog, {"state": ms[1], "consts": {"tau": taus[1]}},
                        4)
        drv.pump(3)                      # mid-flight: A at 3/9, B at 3/4
        drv.checkpoint()
        del drv                          # "kill"

        drv2 = tdp.FleetDriver.restore(ck, {"demo": prog})
        rA, rB = drv2._tickets[tA.id], drv2._tickets[tB.id]
        assert rA.step == 3 and not rA.done
        assert rB.step == 3 and not rB.done
        assert rA.rng is not None
        np.testing.assert_array_equal(np.asarray(rA.rng),
                                      np.asarray(jax.random.PRNGKey(3)))
        final = drv2.drain()
        assert drv2._tickets[tA.id].step == 9

        # uninterrupted reference driver
        ref = tdp.FleetDriver("xla", batch=2)
        uA = ref.submit(prog, {"state": ms[0], "consts": {"tau": taus[0]}},
                        9)
        uB = ref.submit(prog, {"state": ms[1], "consts": {"tau": taus[1]}},
                        4)
        rfinal = ref.drain()
        np.testing.assert_array_equal(np.asarray(final[tA.id]["a"]),
                                      np.asarray(rfinal[uA.id]["a"]))
        np.testing.assert_array_equal(np.asarray(final[tB.id]["a"]),
                                      np.asarray(rfinal[uB.id]["a"]))

    def test_completed_tickets_restore_completed(self, tmp_path):
        prog = make_prog(tdp.TargetConst(np.float32(1.0)))
        ck = str(tmp_path / "ck")
        drv = tdp.FleetDriver("xla", batch=2, checkpoint_dir=ck)
        t = drv.submit(prog, {"state": members(1)[0]}, 2)
        drv.drain()
        drv.checkpoint()
        drv2 = tdp.FleetDriver.restore(ck, prog)
        assert drv2._tickets[t.id].done
        assert drv2.drain()[t.id]["a"].shape == (2,) + GRID

    def test_periodic_checkpoint_cadence(self, tmp_path):
        from repro.checkpoint.store import latest_step
        prog = make_prog(tdp.TargetConst(np.float32(1.0)))
        ck = str(tmp_path / "ck")
        drv = tdp.FleetDriver("xla", batch=2, checkpoint_dir=ck,
                              checkpoint_every=2)
        drv.submit(prog, {"state": members(1)[0]}, 5)
        drv.drain()
        assert latest_step(ck) is not None    # cadence fired mid-drain


# ---------------------------------------------------------------------------
# sharded fleet (vmap outside shard_map), in a fake-device subprocess
# ---------------------------------------------------------------------------

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, timeout=600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


@pytest.mark.slow
class TestShardedFleet:
    def test_slab_sharded_fleet_matches_single_device(self):
        run_sub("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
assert len(jax.devices()) == 8
from repro import tdp
from repro.lb import programs as lbp
from repro.lb.params import LBParams

consts = lbp.collision_consts(np.float32, **LBParams().as_kwargs())
prog = lbp.fused_program("two_launch", consts)
grid = (8, 8, 8)
mesh = Mesh(np.array(jax.devices()[:2]), ("x",))

rng = np.random.default_rng(0)
B = 3
ms = [{f: jnp.asarray(rng.normal(size=(19,) + grid).astype(np.float32))
       for f in ("f", "g")} for _ in range(B)]
state = tdp.ProgramState.stack(ms)

local = prog.compile(tdp.Target("xla", vvl=64),
                     grid_shape=grid).vmap(B)
shard = prog.compile(tdp.Target("xla", vvl=64, mesh=mesh,
                                shard_axis="x"),
                     grid_shape=grid).vmap(B)
a = local.run(state, 3)
b = shard.run(state, 3)
for f in ("f", "g"):
    np.testing.assert_array_equal(np.asarray(a[f]), np.asarray(b[f]))
print("sharded-fleet-ok")
""")
